//! Parser for the StableHLO textual modules JAX and PyTorch emit.
//!
//! The parser consumes the token stream from [`super::lexer`] and produces
//! a [`ModuleInfo`]: function signatures plus one [`OpInfo`] per operation,
//! with the attributes that matter for performance modeling decoded
//! (dot_general dimension numbers, convolution layout/stride/padding,
//! generic integer-list attributes). Everything else — precision configs,
//! frontend metadata, regions of fused reductions — is skipped with
//! correct bracket balancing, so unknown ops never derail the parse.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::lexer::{lex, SpannedTok, Tok};
use super::opinfo::{ConvAttrs, ConvDimLabel, DotDims, FuncInfo, ModuleInfo, OpInfo, ShardingAttr};
use super::types::TensorType;

/// Parse a StableHLO module from text.
pub fn parse_module(text: &str) -> Result<ModuleInfo> {
    let toks = lex(text)?;
    let mut cur = Cursor { toks: &toks, pos: 0 };
    let mut module = ModuleInfo::default();

    while !cur.done() {
        match cur.peek() {
            Some(Tok::Ident(id)) if id == "module" => {
                cur.next();
                if let Some(Tok::Symbol(name)) = cur.peek() {
                    module.name = name.clone();
                    cur.next();
                }
                // `attributes {...}` and then `{` — we just continue; the
                // body statements are handled by the main loop.
                while let Some(t) = cur.peek() {
                    if t.is_punct('{') {
                        cur.next();
                        break;
                    }
                    // Skip `attributes` keyword and its dict.
                    if t.is_punct('{') {
                        break;
                    }
                    if matches!(t, Tok::Ident(w) if w == "attributes") {
                        cur.next();
                        cur.skip_balanced('{', '}')?;
                        continue;
                    }
                    cur.next();
                }
            }
            Some(Tok::Ident(id)) if id == "func.func" => {
                let f = parse_func(&mut cur)?;
                module.funcs.push(f);
            }
            _ => {
                cur.next();
            }
        }
    }
    if module.funcs.is_empty() {
        bail!("no func.func found in module");
    }
    Ok(module)
}

struct Cursor<'a> {
    toks: &'a [SpannedTok],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn done(&self) -> bool {
        self.pos >= self.toks.len()
    }

    fn peek(&self) -> Option<&'a Tok> {
        self.toks.get(self.pos).map(|t| &t.tok)
    }

    fn peek_at(&self, off: usize) -> Option<&'a Tok> {
        self.toks.get(self.pos + off).map(|t| &t.tok)
    }

    fn line(&self) -> usize {
        self.toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|t| t.line)
            .unwrap_or(0)
    }

    fn next(&mut self) -> Option<&'a Tok> {
        let t = self.toks.get(self.pos).map(|t| &t.tok);
        self.pos += 1;
        t
    }

    fn expect_punct(&mut self, c: char) -> Result<()> {
        match self.next() {
            Some(t) if t.is_punct(c) => Ok(()),
            other => bail!(
                "line {}: expected '{}', found {:?}",
                self.line(),
                c,
                other
            ),
        }
    }

    /// Skip a balanced `open...close` block. The cursor must be at or
    /// before the opening token; everything through the matching close is
    /// consumed.
    fn skip_balanced(&mut self, open: char, close: char) -> Result<()> {
        // Advance to the opening token.
        while let Some(t) = self.peek() {
            if t.is_punct(open) {
                break;
            }
            self.next();
        }
        if self.done() {
            bail!("expected '{open}' block");
        }
        let mut depth = 0i64;
        while let Some(t) = self.next() {
            if t.is_punct(open) {
                depth += 1;
            } else if t.is_punct(close) {
                depth -= 1;
                if depth == 0 {
                    return Ok(());
                }
            }
        }
        bail!("unbalanced '{open}{close}' block")
    }

    /// Parse `[i64, i64, ...]`.
    fn int_list(&mut self) -> Result<Vec<i64>> {
        self.expect_punct('[')?;
        let mut out = Vec::new();
        loop {
            match self.next() {
                Some(Tok::Int(v)) => out.push(*v),
                Some(t) if t.is_punct(']') => return Ok(out),
                Some(t) if t.is_punct(',') => continue,
                other => bail!("line {}: bad int list item {:?}", self.line(), other),
            }
        }
    }

    /// Parse `[[a, b], [c, d], ...]` (used by conv `pad`).
    fn int_pair_list(&mut self) -> Result<Vec<(i64, i64)>> {
        self.expect_punct('[')?;
        let mut out = Vec::new();
        loop {
            match self.peek() {
                Some(t) if t.is_punct(']') => {
                    self.next();
                    return Ok(out);
                }
                Some(t) if t.is_punct(',') => {
                    self.next();
                }
                Some(t) if t.is_punct('[') => {
                    let inner = self.int_list()?;
                    if inner.len() != 2 {
                        bail!("line {}: pad entry must have 2 ints", self.line());
                    }
                    out.push((inner[0], inner[1]));
                }
                other => bail!("line {}: bad pad list item {:?}", self.line(), other),
            }
        }
    }

    /// Parse a conv layout list: `[b, f, 0, 1]`.
    fn layout_list(&mut self) -> Result<Vec<ConvDimLabel>> {
        self.expect_punct('[')?;
        let mut out = Vec::new();
        loop {
            match self.next() {
                Some(Tok::Ident(w)) => {
                    out.push(match w.as_str() {
                        "b" => ConvDimLabel::Batch,
                        "f" => ConvDimLabel::Feature,
                        "i" => ConvDimLabel::KernelIn,
                        "o" => ConvDimLabel::KernelOut,
                        other => bail!("line {}: bad conv dim label '{other}'", self.line()),
                    });
                }
                Some(Tok::Int(v)) => out.push(ConvDimLabel::Spatial(*v as usize)),
                Some(t) if t.is_punct(']') => return Ok(out),
                Some(t) if t.is_punct(',') => continue,
                other => bail!("line {}: bad conv layout item {:?}", self.line(), other),
            }
        }
    }
}

fn parse_func(cur: &mut Cursor) -> Result<FuncInfo> {
    // `func.func` already peeked; consume it.
    cur.next();
    // Optional visibility (`public`, `private`).
    if matches!(cur.peek(), Some(Tok::Ident(w)) if w == "public" || w == "private") {
        cur.next();
    }
    let name = match cur.next() {
        Some(Tok::Symbol(s)) => s.clone(),
        other => bail!("line {}: expected function symbol, got {:?}", cur.line(), other),
    };

    // Argument list.
    let mut arg_types = Vec::new();
    cur.expect_punct('(')?;
    loop {
        match cur.peek() {
            Some(t) if t.is_punct(')') => {
                cur.next();
                break;
            }
            Some(t) if t.is_punct(',') => {
                cur.next();
            }
            Some(Tok::SsaId(_)) => {
                cur.next();
                cur.expect_punct(':')?;
                match cur.next() {
                    Some(Tok::TensorType(inner)) => {
                        arg_types.push(TensorType::parse_inner(inner)?);
                    }
                    other => bail!("line {}: expected arg type, got {:?}", cur.line(), other),
                }
                // Optional per-arg attr dict.
                if matches!(cur.peek(), Some(t) if t.is_punct('{')) {
                    cur.skip_balanced('{', '}')?;
                }
            }
            other => bail!("line {}: bad function arg {:?}", cur.line(), other),
        }
    }

    // Optional result types: `-> (t1 {attrs}, t2)` or `-> t`.
    let mut result_types = Vec::new();
    if matches!(cur.peek(), Some(Tok::Arrow)) {
        cur.next();
        match cur.peek() {
            Some(t) if t.is_punct('(') => {
                cur.next();
                loop {
                    match cur.peek() {
                        Some(t) if t.is_punct(')') => {
                            cur.next();
                            break;
                        }
                        Some(t) if t.is_punct(',') => {
                            cur.next();
                        }
                        Some(Tok::TensorType(inner)) => {
                            result_types.push(TensorType::parse_inner(inner)?);
                            cur.next();
                            if matches!(cur.peek(), Some(t) if t.is_punct('{')) {
                                cur.skip_balanced('{', '}')?;
                            }
                        }
                        other => {
                            bail!("line {}: bad result type {:?}", cur.line(), other)
                        }
                    }
                }
            }
            Some(Tok::TensorType(inner)) => {
                result_types.push(TensorType::parse_inner(inner)?);
                cur.next();
            }
            other => bail!("line {}: bad result types {:?}", cur.line(), other),
        }
    }
    // Optional function attr dict: `attributes {...}`.
    if matches!(cur.peek(), Some(Tok::Ident(w)) if w == "attributes") {
        cur.next();
        cur.skip_balanced('{', '}')?;
    }

    // Body.
    cur.expect_punct('{')?;
    let mut ops = Vec::new();
    let mut index = 0usize;
    loop {
        match cur.peek() {
            None => bail!("unterminated function body for @{name}"),
            Some(t) if t.is_punct('}') => {
                cur.next();
                break;
            }
            Some(Tok::Ident(w)) if w == "return" || w == "func.return" => {
                skip_statement(cur)?;
            }
            // Trailing regions of `stablehlo.while` (pretty form prints
            // them *after* the op's type signature): skip balanced.
            Some(Tok::Ident(w)) if w == "cond" || w == "do" => {
                cur.next();
                if matches!(cur.peek(), Some(t) if t.is_punct('{')) {
                    cur.skip_balanced('{', '}')?;
                }
            }
            Some(Tok::SsaId(_)) | Some(Tok::Ident(_)) => {
                if let Some(op) = parse_op(cur, index)? {
                    ops.push(op);
                    index += 1;
                }
            }
            _ => {
                cur.next();
            }
        }
    }

    Ok(FuncInfo {
        name,
        arg_types,
        result_types,
        ops,
    })
}

/// Skip tokens to the end of the current statement: consume the trailing
/// type signature after the top-level ':' (or stop before the next
/// statement start if none is found).
fn skip_statement(cur: &mut Cursor) -> Result<()> {
    let mut depth = 0i64;
    while let Some(t) = cur.peek() {
        match t {
            Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{') => {
                depth += 1;
                cur.next();
            }
            Tok::Punct(')') | Tok::Punct(']') => {
                depth -= 1;
                cur.next();
            }
            Tok::Punct('}') => {
                if depth == 0 {
                    // Function close: leave it for the caller.
                    return Ok(());
                }
                depth -= 1;
                cur.next();
            }
            Tok::Punct(':') if depth == 0 => {
                cur.next();
                consume_type_signature(cur)?;
                return Ok(());
            }
            _ => {
                cur.next();
            }
        }
    }
    Ok(())
}

/// Consume (and discard) a type signature: `tensor<..>`, `(types) -> types`,
/// possibly followed by `-> types`.
fn consume_type_signature(cur: &mut Cursor) -> Result<()> {
    match cur.peek() {
        Some(Tok::TensorType(_)) | Some(Tok::Ident(_)) => {
            cur.next();
        }
        Some(t) if t.is_punct('(') => {
            cur.skip_balanced('(', ')')?;
        }
        _ => return Ok(()),
    }
    if matches!(cur.peek(), Some(Tok::Arrow)) {
        cur.next();
        match cur.peek() {
            Some(Tok::TensorType(_)) | Some(Tok::Ident(_)) => {
                cur.next();
            }
            Some(t) if t.is_punct('(') => {
                cur.skip_balanced('(', ')')?;
            }
            _ => {}
        }
    }
    Ok(())
}

/// Parse one operation statement into an [`OpInfo`].
/// Returns `None` for statements that aren't ops (stray idents).
fn parse_op(cur: &mut Cursor, index: usize) -> Result<Option<OpInfo>> {
    let line = cur.line();

    // Results: `%id =` or `%id:2 =`.
    let mut results = Vec::new();
    while let Some(Tok::SsaId(id)) = cur.peek() {
        results.push(id.clone());
        cur.next();
        // Multi-result arity `:N`.
        if matches!(cur.peek(), Some(t) if t.is_punct(':'))
            && matches!(cur.peek_at(1), Some(Tok::Int(_)))
        {
            cur.next();
            cur.next();
        }
        if matches!(cur.peek(), Some(t) if t.is_punct(',')) {
            cur.next();
            continue;
        }
        break;
    }
    if !results.is_empty() {
        cur.expect_punct('=')?;
    }

    // Op name.
    let op_name = match cur.peek() {
        Some(Tok::Ident(w)) => {
            let w = w.clone();
            cur.next();
            w
        }
        Some(Tok::Str(w)) => {
            // Generic form: `"stablehlo.add"(%0, %1) ...`.
            let w = w.clone();
            cur.next();
            w
        }
        other => {
            bail!("line {line}: expected op name, found {other:?}")
        }
    };

    let mut op = OpInfo {
        index,
        line,
        results,
        op_name,
        operands: Vec::new(),
        operand_types: Vec::new(),
        result_types: Vec::new(),
        dot_dims: None,
        conv_attrs: None,
        int_attrs: BTreeMap::new(),
        callee: None,
        sharding: None,
    };

    // Scan until the top-level ':' that precedes the type signature.
    let mut depth = 0i64;
    let mut pending_ident: Option<String> = None;
    loop {
        let Some(t) = cur.peek() else {
            bail!("line {line}: unterminated op '{}'", op.op_name)
        };
        match t {
            Tok::Punct('(') | Tok::Punct('[') => {
                depth += 1;
                cur.next();
            }
            Tok::Punct(')') | Tok::Punct(']') => {
                depth -= 1;
                cur.next();
            }
            Tok::Punct('{') => {
                // Attr dict or region: operands never live inside braces,
                // except conv's `window = {...}` which we parse explicitly
                // below before getting here.
                if pending_ident.as_deref() == Some("window") {
                    parse_conv_window(cur, &mut op)?;
                    pending_ident = None;
                } else {
                    parse_attr_dict_or_region(cur, &mut op)?;
                }
            }
            Tok::Punct('}') if depth == 0 => {
                // End of enclosing function; op had no type signature.
                break;
            }
            Tok::Punct('}') => {
                depth -= 1;
                cur.next();
            }
            Tok::Punct(':') if depth == 0 => {
                cur.next();
                parse_type_signature(cur, &mut op)?;
                break;
            }
            Tok::SsaId(id) => {
                op.operands.push(id.clone());
                cur.next();
            }
            Tok::Symbol(sym) => {
                if op.callee.is_none() {
                    op.callee = Some(sym.clone());
                }
                cur.next();
            }
            Tok::Ident(w) => {
                let w = w.clone();
                cur.next();
                match w.as_str() {
                    "contracting_dims" => {
                        // `contracting_dims = [1] x [0]`
                        cur.expect_punct('=')?;
                        let lhs = cur.int_list()?;
                        expect_x(cur)?;
                        let rhs = cur.int_list()?;
                        let d = op.dot_dims.get_or_insert_with(DotDims::default);
                        d.lhs_contract = to_usizes(&lhs);
                        d.rhs_contract = to_usizes(&rhs);
                    }
                    "batching_dims" => {
                        cur.expect_punct('=')?;
                        let lhs = cur.int_list()?;
                        expect_x(cur)?;
                        let rhs = cur.int_list()?;
                        let d = op.dot_dims.get_or_insert_with(DotDims::default);
                        d.lhs_batch = to_usizes(&lhs);
                        d.rhs_batch = to_usizes(&rhs);
                    }
                    "dim_numbers" => {
                        // `= [b, f, 0, 1]x[o, i, 0, 1]->[b, f, 0, 1]`
                        cur.expect_punct('=')?;
                        let a = op.conv_attrs.get_or_insert_with(ConvAttrs::default);
                        a.input_layout = cur.layout_list()?;
                        expect_x(cur)?;
                        a.kernel_layout = cur.layout_list()?;
                        match cur.next() {
                            Some(Tok::Arrow) => {}
                            other => bail!("line {line}: expected '->' in dim_numbers, got {other:?}"),
                        }
                        a.output_layout = cur.layout_list()?;
                    }
                    "window" => {
                        // `window = { ... }` — handled when '{' arrives.
                        cur.expect_punct('=')?;
                        pending_ident = Some("window".to_string());
                        continue;
                    }
                    _ => {
                        // Generic `ident = [ints]` attr; other shapes of
                        // attribute are skipped token-by-token.
                        if matches!(cur.peek(), Some(t) if t.is_punct('='))
                            && matches!(cur.peek_at(1), Some(t) if t.is_punct('['))
                        {
                            cur.next(); // '='
                            // Only simple int lists are captured.
                            let save = cur.pos;
                            match cur.int_list() {
                                Ok(list) => {
                                    op.int_attrs.insert(w, list);
                                }
                                Err(_) => {
                                    cur.pos = save;
                                    cur.skip_balanced('[', ']')?;
                                }
                            }
                        }
                    }
                }
                pending_ident = None;
            }
            _ => {
                cur.next();
            }
        }
    }

    // Generic-form dot_dimension_numbers arrive as a RawAngle attr inside
    // the attr dict; parse_attr_dict_or_region handles it.
    Ok(Some(op))
}

fn expect_x(cur: &mut Cursor) -> Result<()> {
    match cur.next() {
        Some(Tok::Ident(w)) if w == "x" => Ok(()),
        other => bail!("line {}: expected 'x', got {:?}", cur.line(), other),
    }
}

fn to_usizes(xs: &[i64]) -> Vec<usize> {
    xs.iter().map(|&x| x.max(0) as usize).collect()
}

/// Parse `window = {stride = [..], pad = [[..]], lhs_dilate = [..], ...}`.
fn parse_conv_window(cur: &mut Cursor, op: &mut OpInfo) -> Result<()> {
    cur.expect_punct('{')?;
    let attrs = op.conv_attrs.get_or_insert_with(ConvAttrs::default);
    loop {
        match cur.peek() {
            Some(t) if t.is_punct('}') => {
                cur.next();
                return Ok(());
            }
            Some(t) if t.is_punct(',') => {
                cur.next();
            }
            Some(Tok::Ident(w)) => {
                let w = w.clone();
                cur.next();
                cur.expect_punct('=')?;
                match w.as_str() {
                    "stride" => attrs.strides = to_usizes(&cur.int_list()?),
                    "pad" => attrs.pads = cur.int_pair_list()?,
                    "lhs_dilate" => attrs.lhs_dilation = to_usizes(&cur.int_list()?),
                    "rhs_dilate" => attrs.rhs_dilation = to_usizes(&cur.int_list()?),
                    _ => {
                        // `reverse = [false, false]` and friends: skip list
                        // or single token.
                        if matches!(cur.peek(), Some(t) if t.is_punct('[')) {
                            cur.skip_balanced('[', ']')?;
                        } else {
                            cur.next();
                        }
                    }
                }
            }
            other => bail!("line {}: bad window attr {:?}", cur.line(), other),
        }
    }
}

/// Parse an attr dict `{...}` (capturing conv group counts and generic-form
/// dot dimension numbers) or skip a region.
fn parse_attr_dict_or_region(cur: &mut Cursor, op: &mut OpInfo) -> Result<()> {
    // Peek inside: a region starts with `^` or an SSA statement; an attr
    // dict starts with `ident =` or `}`. We conservatively scan with
    // balancing and capture the few attrs we care about.
    let start = cur.pos;
    cur.expect_punct('{')?;
    let mut depth = 1i64;
    while depth > 0 {
        let Some(t) = cur.peek() else {
            bail!("line {}: unterminated '{{' block", cur.line())
        };
        match t {
            Tok::Punct('{') => {
                depth += 1;
                cur.next();
            }
            Tok::Punct('}') => {
                depth -= 1;
                cur.next();
            }
            Tok::Ident(w) if depth == 1 => {
                let w = w.clone();
                cur.next();
                if !matches!(cur.peek(), Some(t) if t.is_punct('=')) {
                    continue;
                }
                cur.next(); // '='
                match (w.as_str(), cur.peek()) {
                    ("batch_group_count", Some(Tok::Int(v))) => {
                        let v = *v;
                        cur.next();
                        op.conv_attrs
                            .get_or_insert_with(ConvAttrs::default)
                            .batch_group_count = v.max(0) as usize;
                    }
                    ("feature_group_count", Some(Tok::Int(v))) => {
                        let v = *v;
                        cur.next();
                        op.conv_attrs
                            .get_or_insert_with(ConvAttrs::default)
                            .feature_group_count = v.max(0) as usize;
                    }
                    ("dot_dimension_numbers", Some(Tok::RawAngle { head, body }))
                        if head.starts_with("#stablehlo") =>
                    {
                        op.dot_dims = Some(parse_dot_attr(body)?);
                        cur.next();
                    }
                    ("mhlo.sharding", Some(Tok::Str(s))) => {
                        let parsed = ShardingAttr::parse(s);
                        cur.next();
                        if op.sharding.is_none() {
                            op.sharding = parsed;
                        }
                    }
                    // Scalar integer attributes collectives carry
                    // (`all_gather_dim = 0 : i64`, ...).
                    (key, Some(Tok::Int(v)))
                        if matches!(
                            key,
                            "all_gather_dim" | "scatter_dimension" | "split_dimension"
                                | "concat_dimension"
                        ) =>
                    {
                        let v = *v;
                        cur.next();
                        op.int_attrs.insert(key.to_string(), vec![v]);
                    }
                    _ => {}
                }
            }
            _ => {
                cur.next();
            }
        }
    }
    let _ = start;
    Ok(())
}

/// Parse the generic `#stablehlo.dot<...>` attribute body, e.g.
/// `lhs_batching_dimensions = [0], rhs_batching_dimensions = [0],
///  lhs_contracting_dimensions = [2], rhs_contracting_dimensions = [1]`.
fn parse_dot_attr(body: &str) -> Result<DotDims> {
    let mut dims = DotDims::default();
    for part in body.split(',') {
        let part = part.trim();
        let Some((key, val)) = part.split_once('=') else {
            continue;
        };
        let list = parse_bracket_ints(val)?;
        match key.trim() {
            "lhs_batching_dimensions" => dims.lhs_batch = list,
            "rhs_batching_dimensions" => dims.rhs_batch = list,
            "lhs_contracting_dimensions" => dims.lhs_contract = list,
            "rhs_contracting_dimensions" => dims.rhs_contract = list,
            _ => {}
        }
    }
    Ok(dims)
}

fn parse_bracket_ints(s: &str) -> Result<Vec<usize>> {
    let s = s.trim();
    let inner = s
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .with_context(|| format!("expected [..] list, got '{s}'"))?;
    let inner = inner.trim();
    if inner.is_empty() {
        return Ok(vec![]);
    }
    inner
        .split(',')
        .map(|p| {
            p.trim()
                .parse::<usize>()
                .with_context(|| format!("bad int '{p}'"))
        })
        .collect()
}

/// Parse the trailing type signature and fill operand/result types.
fn parse_type_signature(cur: &mut Cursor, op: &mut OpInfo) -> Result<()> {
    match cur.peek() {
        // `(t1, t2) -> t3` function type.
        Some(t) if t.is_punct('(') => {
            cur.next();
            loop {
                match cur.peek() {
                    Some(t) if t.is_punct(')') => {
                        cur.next();
                        break;
                    }
                    Some(t) if t.is_punct(',') => {
                        cur.next();
                    }
                    Some(Tok::TensorType(inner)) => {
                        op.operand_types.push(TensorType::parse_inner(inner)?);
                        cur.next();
                    }
                    other => bail!(
                        "line {}: bad operand type {:?} in signature",
                        cur.line(),
                        other
                    ),
                }
            }
            if matches!(cur.peek(), Some(Tok::Arrow)) {
                cur.next();
                match cur.peek() {
                    Some(Tok::TensorType(inner)) => {
                        op.result_types.push(TensorType::parse_inner(inner)?);
                        cur.next();
                    }
                    Some(t) if t.is_punct('(') => {
                        cur.next();
                        loop {
                            match cur.peek() {
                                Some(t) if t.is_punct(')') => {
                                    cur.next();
                                    break;
                                }
                                Some(t) if t.is_punct(',') => {
                                    cur.next();
                                }
                                Some(Tok::TensorType(inner)) => {
                                    op.result_types.push(TensorType::parse_inner(inner)?);
                                    cur.next();
                                }
                                other => bail!(
                                    "line {}: bad result type {:?} in signature",
                                    cur.line(),
                                    other
                                ),
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
        // Single type: operands and result share it.
        Some(Tok::TensorType(inner)) => {
            let t = TensorType::parse_inner(inner)?;
            cur.next();
            for _ in 0..op.operands.len().max(1) {
                op.operand_types.push(t.clone());
            }
            op.result_types.push(t);
        }
        other => bail!("line {}: bad type signature start {:?}", cur.line(), other),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::types::DType;

    const MLP: &str = r#"
module @jit_f attributes {mhlo.num_partitions = 1 : i32} {
  func.func public @main(%arg0: tensor<128x256xbf16>, %arg1: tensor<256x512xbf16>, %arg2: tensor<128x512xbf16>) -> (tensor<128x512xbf16> {jax.result_info = "result"}) {
    %0 = stablehlo.dot_general %arg0, %arg1, contracting_dims = [1] x [0], precision = [DEFAULT, DEFAULT] : (tensor<128x256xbf16>, tensor<256x512xbf16>) -> tensor<128x512xbf16>
    %1 = stablehlo.add %0, %arg2 : tensor<128x512xbf16>
    %cst = stablehlo.constant dense<0.000000e+00> : tensor<bf16>
    %2 = stablehlo.broadcast_in_dim %cst, dims = [] : (tensor<bf16>) -> tensor<128x512xbf16>
    %3 = stablehlo.maximum %1, %2 : tensor<128x512xbf16>
    return %3 : tensor<128x512xbf16>
  }
}
"#;

    #[test]
    fn parse_mlp_module() {
        let m = parse_module(MLP).unwrap();
        assert_eq!(m.name, "jit_f");
        let f = m.entry().unwrap();
        assert_eq!(f.name, "main");
        assert_eq!(f.arg_types.len(), 3);
        assert_eq!(f.result_types.len(), 1);
        assert_eq!(f.ops.len(), 5);
    }

    #[test]
    fn dot_general_dims_extracted() {
        let m = parse_module(MLP).unwrap();
        let dot = &m.entry().unwrap().ops[0];
        assert_eq!(dot.op_name, "stablehlo.dot_general");
        assert_eq!(dot.operands, vec!["arg0", "arg1"]);
        let d = dot.dot_dims.as_ref().unwrap();
        assert_eq!(d.lhs_contract, vec![1]);
        assert_eq!(d.rhs_contract, vec![0]);
        assert!(d.lhs_batch.is_empty());
        assert_eq!(dot.operand_types.len(), 2);
        assert_eq!(dot.operand_types[0].dims, vec![128, 256]);
        assert_eq!(dot.result_types[0].dims, vec![128, 512]);
    }

    #[test]
    fn elementwise_single_type_signature() {
        let m = parse_module(MLP).unwrap();
        let add = &m.entry().unwrap().ops[1];
        assert_eq!(add.short_name(), "add");
        assert_eq!(add.operands, vec!["0", "arg2"]);
        assert_eq!(add.operand_types.len(), 2);
        assert_eq!(add.result_types[0].dims, vec![128, 512]);
        assert_eq!(add.result_types[0].dtype, DType::Bf16);
    }

    #[test]
    fn constant_and_broadcast() {
        let m = parse_module(MLP).unwrap();
        let f = m.entry().unwrap();
        assert_eq!(f.ops[2].short_name(), "constant");
        assert!(f.ops[2].operands.is_empty());
        let bcast = &f.ops[3];
        assert_eq!(bcast.short_name(), "broadcast_in_dim");
        assert_eq!(bcast.result_types[0].num_elements(), 128 * 512);
        assert_eq!(bcast.int_attrs.get("dims"), Some(&vec![]));
    }

    const CONV: &str = r#"
module @jit_conv attributes {mhlo.num_partitions = 1 : i32} {
  func.func public @main(%arg0: tensor<1x3x32x32xbf16>, %arg1: tensor<16x3x3x3xbf16>) -> (tensor<1x16x16x16xbf16>) {
    %0 = stablehlo.convolution(%arg0, %arg1) dim_numbers = [b, f, 0, 1]x[o, i, 0, 1]->[b, f, 0, 1], window = {stride = [2, 2], pad = [[0, 1], [0, 1]], lhs_dilate = [1, 1], rhs_dilate = [1, 1], reverse = [false, false]} {batch_group_count = 1 : i64, feature_group_count = 1 : i64, precision_config = [#stablehlo<precision DEFAULT>, #stablehlo<precision DEFAULT>]} : (tensor<1x3x32x32xbf16>, tensor<16x3x3x3xbf16>) -> tensor<1x16x16x16xbf16>
    return %0 : tensor<1x16x16x16xbf16>
  }
}
"#;

    #[test]
    fn conv_attrs_extracted() {
        let m = parse_module(CONV).unwrap();
        let conv = &m.entry().unwrap().ops[0];
        assert_eq!(conv.short_name(), "convolution");
        assert_eq!(conv.operands, vec!["arg0", "arg1"]);
        let a = conv.conv_attrs.as_ref().unwrap();
        assert_eq!(a.strides, vec![2, 2]);
        assert_eq!(a.pads, vec![(0, 1), (0, 1)]);
        assert_eq!(a.feature_group_count, 1);
        assert_eq!(a.input_layout[0], ConvDimLabel::Batch);
        assert_eq!(a.input_layout[1], ConvDimLabel::Feature);
        assert_eq!(a.kernel_layout[0], ConvDimLabel::KernelOut);
        assert_eq!(a.output_layout.len(), 4);
        assert_eq!(conv.operand_types[1].dims, vec![16, 3, 3, 3]);
        assert_eq!(conv.result_types[0].dims, vec![1, 16, 16, 16]);
    }

    #[test]
    fn generic_form_dot_attr() {
        let text = r#"
module {
  func.func @main(%arg0: tensor<2x3x4xf32>, %arg1: tensor<2x4x5xf32>) -> tensor<2x3x5xf32> {
    %0 = "stablehlo.dot_general"(%arg0, %arg1) {dot_dimension_numbers = #stablehlo.dot<lhs_batching_dimensions = [0], rhs_batching_dimensions = [0], lhs_contracting_dimensions = [2], rhs_contracting_dimensions = [1]>} : (tensor<2x3x4xf32>, tensor<2x4x5xf32>) -> tensor<2x3x5xf32>
    return %0 : tensor<2x3x5xf32>
  }
}
"#;
        let m = parse_module(text).unwrap();
        let dot = &m.entry().unwrap().ops[0];
        let d = dot.dot_dims.as_ref().unwrap();
        assert_eq!(d.lhs_batch, vec![0]);
        assert_eq!(d.rhs_batch, vec![0]);
        assert_eq!(d.lhs_contract, vec![2]);
        assert_eq!(d.rhs_contract, vec![1]);
    }

    #[test]
    fn reduce_applies_form() {
        let text = r#"
module {
  func.func @main(%arg0: tensor<8x128xf32>) -> tensor<8xf32> {
    %cst = stablehlo.constant dense<0.000000e+00> : tensor<f32>
    %0 = stablehlo.reduce(%arg0 init: %cst) applies stablehlo.add across dimensions = [1] : (tensor<8x128xf32>, tensor<f32>) -> tensor<8xf32>
    return %0 : tensor<8xf32>
  }
}
"#;
        let m = parse_module(text).unwrap();
        let red = &m.entry().unwrap().ops[1];
        assert_eq!(red.short_name(), "reduce");
        assert_eq!(red.operands, vec!["arg0", "cst"]);
        assert_eq!(red.int_attrs.get("dimensions"), Some(&vec![1]));
        assert_eq!(red.result_types[0].dims, vec![8]);
    }

    #[test]
    fn sharding_attr_captured() {
        let text = r#"
module @m {
  func.func @main(%a: tensor<64x64xf32>, %b: tensor<64x64xf32>) -> tensor<64x64xf32> {
    %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] {mhlo.sharding = "{devices=[2,1]<=[2]}"} : (tensor<64x64xf32>, tensor<64x64xf32>) -> tensor<64x64xf32>
    %1 = stablehlo.add %0, %a {mhlo.sharding = "{replicated}"} : tensor<64x64xf32>
    return %1 : tensor<64x64xf32>
  }
}
"#;
        let m = parse_module(text).unwrap();
        let f = m.entry().unwrap();
        assert_eq!(
            f.ops[0].sharding,
            Some(ShardingAttr::Devices { mesh: vec![2, 1] })
        );
        assert_eq!(f.ops[1].sharding, Some(ShardingAttr::Replicated));
    }

    #[test]
    fn collective_generic_form_parsed() {
        let text = r#"
module @m {
  func.func @main(%a: tensor<8x128xf32>) -> tensor<32x128xf32> {
    %0 = "stablehlo.all_gather"(%a) {all_gather_dim = 0 : i64, replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>} : (tensor<8x128xf32>) -> tensor<32x128xf32>
    return %0 : tensor<32x128xf32>
  }
}
"#;
        let m = parse_module(text).unwrap();
        let op = &m.entry().unwrap().ops[0];
        assert_eq!(op.short_name(), "all_gather");
        assert_eq!(op.int_attrs.get("all_gather_dim"), Some(&vec![0]));
        assert_eq!(op.operand_types[0].dims, vec![8, 128]);
        assert_eq!(op.result_types[0].dims, vec![32, 128]);
    }

    #[test]
    fn no_func_fails() {
        assert!(parse_module("module @m attributes {a = 1 : i32} { }").is_err());
    }

    #[test]
    fn multiple_funcs_entry_selection() {
        let text = r#"
module {
  func.func private @helper(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = stablehlo.negate %arg0 : tensor<4xf32>
    return %0 : tensor<4xf32>
  }
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = stablehlo.abs %arg0 : tensor<4xf32>
    return %0 : tensor<4xf32>
  }
}
"#;
        let m = parse_module(text).unwrap();
        assert_eq!(m.funcs.len(), 2);
        assert_eq!(m.entry().unwrap().name, "main");
    }
}

#[cfg(test)]
mod region_tests {
    use super::*;

    /// Real jax output for a `lax.while_loop` body (pretty-printed while
    /// with trailing cond/do regions) — the parser must survive it and
    /// keep classifying the surrounding ops.
    const WHILE_IR: &str = include_str!("../../tests/fixtures/while_loop.stablehlo.txt");

    #[test]
    fn while_loop_module_parses() {
        let m = parse_module(WHILE_IR).unwrap();
        let f = m.entry().unwrap();
        assert_eq!(f.arg_types[0].dims, vec![8, 128]);
        // The while op itself is recorded; region bodies are skipped, so
        // none of the region-local ops (sine/multiply) leak out.
        assert!(f.ops.iter().any(|o| o.short_name() == "while"));
        assert!(!f.ops.iter().any(|o| o.short_name() == "sine"));
        assert!(!f.ops.iter().any(|o| o.short_name() == "multiply"));
    }

    #[test]
    fn while_op_records_operands_and_type() {
        let m = parse_module(WHILE_IR).unwrap();
        let f = m.entry().unwrap();
        let w = f.ops.iter().find(|o| o.short_name() == "while").unwrap();
        assert!(w.operands.len() >= 2);
        assert!(!w.result_types.is_empty());
    }
}
