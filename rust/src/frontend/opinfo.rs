//! The uniform operator metadata record the frontend extracts for every
//! StableHLO operation (the paper's `OpInfo` structure).

use std::collections::BTreeMap;

use super::types::TensorType;

/// `dot_general` dimension numbers.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DotDims {
    /// Batch dims of the lhs.
    pub lhs_batch: Vec<usize>,
    /// Batch dims of the rhs.
    pub rhs_batch: Vec<usize>,
    /// Contracting dims of the lhs.
    pub lhs_contract: Vec<usize>,
    /// Contracting dims of the rhs.
    pub rhs_contract: Vec<usize>,
}

/// One dimension label in a convolution `dim_numbers` spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvDimLabel {
    /// `b` — batch.
    Batch,
    /// `f` — feature (input/output channels on lhs/output).
    Feature,
    /// `i` — kernel input-feature dim.
    KernelIn,
    /// `o` — kernel output-feature dim.
    KernelOut,
    /// Numbered spatial dimension.
    Spatial(usize),
}

/// Convolution attributes extracted from the pretty-printed form.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConvAttrs {
    /// Input (ifmap) dimension labels, e.g. `b01f`.
    pub input_layout: Vec<ConvDimLabel>,
    /// Kernel dimension labels, e.g. `01io`.
    pub kernel_layout: Vec<ConvDimLabel>,
    /// Output dimension labels.
    pub output_layout: Vec<ConvDimLabel>,
    /// Window stride per spatial dim.
    pub strides: Vec<usize>,
    /// (low, high) padding per spatial dim.
    pub pads: Vec<(i64, i64)>,
    /// Input (lhs) dilation per spatial dim.
    pub lhs_dilation: Vec<usize>,
    /// Kernel (rhs) dilation per spatial dim.
    pub rhs_dilation: Vec<usize>,
    /// Grouped-convolution feature groups.
    pub feature_group_count: usize,
    /// Batch groups.
    pub batch_group_count: usize,
}

/// Parsed `mhlo.sharding` annotation (the GSPMD sharding attribute XLA
/// attaches to partitioned modules). Only the structure relevant to the
/// distributed estimator is kept: whether the value is replicated,
/// pinned to one device, or tiled over a device mesh.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardingAttr {
    /// `{replicated}` — every chip holds the full value.
    Replicated,
    /// `{maximal device=N}` — the value lives on one device.
    Maximal {
        /// The owning device id.
        device: usize,
    },
    /// `{devices=[a,b,...]...}` — tiled: `mesh[i]` shards along tensor
    /// axis `i` (trailing iota/permutation device lists are ignored).
    Devices {
        /// Shards along each tensor axis.
        mesh: Vec<usize>,
    },
}

impl ShardingAttr {
    /// Parse the textual form, e.g. `{devices=[2,1]<=[2]}`,
    /// `{devices=[2,2]0,1,2,3}`, `{replicated}`, `{maximal device=0}`.
    /// Returns `None` for forms we do not model.
    pub fn parse(text: &str) -> Option<ShardingAttr> {
        let s = text.trim();
        let s = s.strip_prefix('{').unwrap_or(s);
        let s = s.strip_suffix('}').unwrap_or(s).trim();
        if s.starts_with("replicated") {
            return Some(ShardingAttr::Replicated);
        }
        if s.starts_with("maximal") {
            let digits: String = s
                .split("device=")
                .nth(1)
                .unwrap_or("")
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            return Some(ShardingAttr::Maximal {
                device: digits.parse().unwrap_or(0),
            });
        }
        if let Some(rest) = s.strip_prefix("devices=") {
            let inner = rest.strip_prefix('[')?.split(']').next()?;
            let mesh: Option<Vec<usize>> = inner
                .split(',')
                .map(|p| p.trim().parse::<usize>().ok())
                .collect();
            let mut mesh = mesh?;
            // GSPMD `{devices=[1,4]<=[4] last_tile_dim_replicate}`: the
            // trailing mesh dim replicates rather than tiles — drop it
            // so the value is not misread as model-parallel.
            if rest.contains("last_tile_dim_replicate") {
                mesh.pop();
            }
            return Some(ShardingAttr::Devices { mesh });
        }
        None
    }

    /// True when no tensor axis is split (replicated or single-device).
    pub fn is_replicated(&self) -> bool {
        match self {
            ShardingAttr::Replicated | ShardingAttr::Maximal { .. } => true,
            ShardingAttr::Devices { mesh } => mesh.iter().all(|&d| d <= 1),
        }
    }

    /// True when the split is along a non-leading axis only (model
    /// parallelism for a GEMM: the output needs an all-gather to get
    /// back to the row-sharded layout the estimator assumes).
    pub fn model_parallel(&self) -> bool {
        match self {
            ShardingAttr::Devices { mesh } => {
                mesh.first().copied().unwrap_or(1) <= 1
                    && mesh.iter().skip(1).any(|&d| d > 1)
            }
            _ => false,
        }
    }
}

/// Uniform per-operation record: type, operands, shapes, dtypes and the
/// attributes relevant to performance modeling.
#[derive(Debug, Clone, PartialEq)]
pub struct OpInfo {
    /// Position of the op within its function body.
    pub index: usize,
    /// Source line in the StableHLO text (diagnostics).
    pub line: usize,
    /// Result SSA ids (no `%`).
    pub results: Vec<String>,
    /// Fully qualified op name, e.g. `stablehlo.dot_general`.
    pub op_name: String,
    /// Operand SSA ids (no `%`).
    pub operands: Vec<String>,
    /// Operand tensor types (parallel to `operands` when the op carries a
    /// function-type signature; single-type ops repeat the one type).
    pub operand_types: Vec<TensorType>,
    /// Result tensor types.
    pub result_types: Vec<TensorType>,
    /// dot_general dimension numbers, if this is a dot_general.
    pub dot_dims: Option<DotDims>,
    /// Convolution attributes, if this is a convolution.
    pub conv_attrs: Option<ConvAttrs>,
    /// Generic integer-list attributes (`dims = [...]`, `dimensions = [...]`).
    pub int_attrs: BTreeMap<String, Vec<i64>>,
    /// Callee symbol for `call` / `func.call` ops.
    pub callee: Option<String>,
    /// Parsed `mhlo.sharding` attribute, if the op carries one.
    pub sharding: Option<ShardingAttr>,
}

impl OpInfo {
    /// Short op name without the dialect prefix (`add`, `dot_general`).
    pub fn short_name(&self) -> &str {
        self.op_name
            .rsplit_once('.')
            .map(|(_, s)| s)
            .unwrap_or(&self.op_name)
    }

    /// The primary output type (first result), if any.
    pub fn out_type(&self) -> Option<&TensorType> {
        self.result_types.first()
    }

    /// Total output elements (0 if no result type was recorded).
    pub fn out_elements(&self) -> u64 {
        self.out_type().map(|t| t.num_elements()).unwrap_or(0)
    }
}

/// A parsed function: signature plus op sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct FuncInfo {
    /// Function symbol name (no `@`).
    pub name: String,
    /// Argument tensor types, in order.
    pub arg_types: Vec<TensorType>,
    /// Result tensor types, in order.
    pub result_types: Vec<TensorType>,
    /// Body operations in SSA order.
    pub ops: Vec<OpInfo>,
}

/// A parsed module: one or more functions (entry point is usually `main`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ModuleInfo {
    /// Module symbol name (no `@`).
    pub name: String,
    /// Functions, entry usually named `main`.
    pub funcs: Vec<FuncInfo>,
}

impl ModuleInfo {
    /// The entry function: `main` if present, else the first function.
    pub fn entry(&self) -> Option<&FuncInfo> {
        self.funcs
            .iter()
            .find(|f| f.name == "main")
            .or_else(|| self.funcs.first())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharding_attr_forms() {
        assert_eq!(
            ShardingAttr::parse("{replicated}"),
            Some(ShardingAttr::Replicated)
        );
        assert_eq!(
            ShardingAttr::parse("{maximal device=3}"),
            Some(ShardingAttr::Maximal { device: 3 })
        );
        assert_eq!(
            ShardingAttr::parse("{devices=[4,1]<=[4]}"),
            Some(ShardingAttr::Devices { mesh: vec![4, 1] })
        );
        assert_eq!(
            ShardingAttr::parse("{devices=[2,2]0,1,2,3}"),
            Some(ShardingAttr::Devices { mesh: vec![2, 2] })
        );
        // The replicated trailing tile dim must not read as tiling.
        let ltdr = ShardingAttr::parse("{devices=[1,4]<=[4] last_tile_dim_replicate}").unwrap();
        assert_eq!(ltdr, ShardingAttr::Devices { mesh: vec![1] });
        assert!(ltdr.is_replicated());
        assert!(!ltdr.model_parallel());
        assert_eq!(ShardingAttr::parse("{manual}"), None);
    }

    #[test]
    fn sharding_attr_predicates() {
        assert!(ShardingAttr::Replicated.is_replicated());
        assert!(ShardingAttr::Maximal { device: 0 }.is_replicated());
        assert!(ShardingAttr::Devices { mesh: vec![1, 1] }.is_replicated());
        let row = ShardingAttr::Devices { mesh: vec![4, 1] };
        assert!(!row.is_replicated());
        assert!(!row.model_parallel());
        let col = ShardingAttr::Devices { mesh: vec![1, 4] };
        assert!(col.model_parallel());
    }
}
