//! The uniform operator metadata record the frontend extracts for every
//! StableHLO operation (the paper's `OpInfo` structure).

use std::collections::BTreeMap;

use super::types::TensorType;

/// `dot_general` dimension numbers.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DotDims {
    pub lhs_batch: Vec<usize>,
    pub rhs_batch: Vec<usize>,
    pub lhs_contract: Vec<usize>,
    pub rhs_contract: Vec<usize>,
}

/// One dimension label in a convolution `dim_numbers` spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvDimLabel {
    /// `b` — batch.
    Batch,
    /// `f` — feature (input/output channels on lhs/output).
    Feature,
    /// `i` — kernel input-feature dim.
    KernelIn,
    /// `o` — kernel output-feature dim.
    KernelOut,
    /// Numbered spatial dimension.
    Spatial(usize),
}

/// Convolution attributes extracted from the pretty-printed form.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConvAttrs {
    pub input_layout: Vec<ConvDimLabel>,
    pub kernel_layout: Vec<ConvDimLabel>,
    pub output_layout: Vec<ConvDimLabel>,
    pub strides: Vec<usize>,
    /// (low, high) padding per spatial dim.
    pub pads: Vec<(i64, i64)>,
    pub lhs_dilation: Vec<usize>,
    pub rhs_dilation: Vec<usize>,
    pub feature_group_count: usize,
    pub batch_group_count: usize,
}

/// Uniform per-operation record: type, operands, shapes, dtypes and the
/// attributes relevant to performance modeling.
#[derive(Debug, Clone, PartialEq)]
pub struct OpInfo {
    /// Position of the op within its function body.
    pub index: usize,
    /// Source line in the StableHLO text (diagnostics).
    pub line: usize,
    /// Result SSA ids (no `%`).
    pub results: Vec<String>,
    /// Fully qualified op name, e.g. `stablehlo.dot_general`.
    pub op_name: String,
    /// Operand SSA ids (no `%`).
    pub operands: Vec<String>,
    /// Operand tensor types (parallel to `operands` when the op carries a
    /// function-type signature; single-type ops repeat the one type).
    pub operand_types: Vec<TensorType>,
    /// Result tensor types.
    pub result_types: Vec<TensorType>,
    /// dot_general dimension numbers, if this is a dot_general.
    pub dot_dims: Option<DotDims>,
    /// Convolution attributes, if this is a convolution.
    pub conv_attrs: Option<ConvAttrs>,
    /// Generic integer-list attributes (`dims = [...]`, `dimensions = [...]`).
    pub int_attrs: BTreeMap<String, Vec<i64>>,
    /// Callee symbol for `call` / `func.call` ops.
    pub callee: Option<String>,
}

impl OpInfo {
    /// Short op name without the dialect prefix (`add`, `dot_general`).
    pub fn short_name(&self) -> &str {
        self.op_name
            .rsplit_once('.')
            .map(|(_, s)| s)
            .unwrap_or(&self.op_name)
    }

    /// The primary output type (first result), if any.
    pub fn out_type(&self) -> Option<&TensorType> {
        self.result_types.first()
    }

    /// Total output elements (0 if no result type was recorded).
    pub fn out_elements(&self) -> u64 {
        self.out_type().map(|t| t.num_elements()).unwrap_or(0)
    }
}

/// A parsed function: signature plus op sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct FuncInfo {
    pub name: String,
    pub arg_types: Vec<TensorType>,
    pub result_types: Vec<TensorType>,
    pub ops: Vec<OpInfo>,
}

/// A parsed module: one or more functions (entry point is usually `main`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ModuleInfo {
    pub name: String,
    pub funcs: Vec<FuncInfo>,
}

impl ModuleInfo {
    /// The entry function: `main` if present, else the first function.
    pub fn entry(&self) -> Option<&FuncInfo> {
        self.funcs
            .iter()
            .find(|f| f.name == "main")
            .or_else(|| self.funcs.first())
    }
}
