//! Tensor element types and shaped tensor types, with parsing of the MLIR
//! textual form (`tensor<1x3x32x32xbf16>`, `tensor<f32>`, ...).

use anyhow::{bail, Result};

/// Element data type of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// Brain float 16.
    Bf16,
    /// IEEE half.
    F16,
    /// IEEE single.
    F32,
    /// IEEE double.
    F64,
    /// 1-bit predicate.
    I1,
    /// Signed 8-bit.
    I8,
    /// Signed 16-bit.
    I16,
    /// Signed 32-bit.
    I32,
    /// Signed 64-bit.
    I64,
    /// Unsigned 8-bit.
    U8,
    /// Unsigned 16-bit.
    U16,
    /// Unsigned 32-bit.
    U32,
    /// Unsigned 64-bit.
    U64,
}

impl DType {
    /// Parse a StableHLO element-type name (`bf16`, `f32`, ...).
    pub fn parse(s: &str) -> Option<DType> {
        Some(match s {
            "bf16" => DType::Bf16,
            "f16" => DType::F16,
            "f32" => DType::F32,
            "f64" => DType::F64,
            "i1" => DType::I1,
            "i8" => DType::I8,
            "i16" => DType::I16,
            "i32" => DType::I32,
            "i64" => DType::I64,
            "ui8" | "u8" => DType::U8,
            "ui16" | "u16" => DType::U16,
            "ui32" | "u32" => DType::U32,
            "ui64" | "u64" => DType::U64,
            _ => return None,
        })
    }

    /// The StableHLO spelling.
    pub fn name(&self) -> &'static str {
        match self {
            DType::Bf16 => "bf16",
            DType::F16 => "f16",
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I1 => "i1",
            DType::I8 => "i8",
            DType::I16 => "i16",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::U8 => "ui8",
            DType::U16 => "ui16",
            DType::U32 => "ui32",
            DType::U64 => "ui64",
        }
    }

    /// Size of one element in bytes (i1 counts as one byte, as stored).
    pub fn bytes(&self) -> usize {
        match self {
            DType::I1 | DType::I8 | DType::U8 => 1,
            DType::Bf16 | DType::F16 | DType::I16 | DType::U16 => 2,
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F64 | DType::I64 | DType::U64 => 8,
        }
    }

    /// Is this a floating-point type?
    pub fn is_float(&self) -> bool {
        matches!(self, DType::Bf16 | DType::F16 | DType::F32 | DType::F64)
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A ranked tensor type: shape + element type. Scalars have rank 0.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorType {
    /// Dimensions, outermost first (empty = scalar).
    pub dims: Vec<usize>,
    /// Element type.
    pub dtype: DType,
}

impl TensorType {
    /// A tensor type from explicit dims and element type.
    pub fn new(dims: Vec<usize>, dtype: DType) -> TensorType {
        TensorType { dims, dtype }
    }

    /// A rank-0 tensor.
    pub fn scalar(dtype: DType) -> TensorType {
        TensorType { dims: vec![], dtype }
    }

    /// Total element count (1 for scalars).
    pub fn num_elements(&self) -> u64 {
        self.dims.iter().map(|&d| d as u64).product()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total byte footprint (elements x element width).
    pub fn size_bytes(&self) -> u64 {
        self.num_elements() * self.dtype.bytes() as u64
    }

    /// Parse the *inside* of `tensor<...>`: e.g. `1x3x32x32xbf16`, `f32`,
    /// `128x256xbf16`. Dynamic dims (`?`) are rejected — the simulator
    /// needs static shapes.
    pub fn parse_inner(inner: &str) -> Result<TensorType> {
        let inner = inner.trim();
        if inner.is_empty() {
            bail!("empty tensor type");
        }
        // Split on 'x' but the final segment is the dtype, which itself
        // contains no 'x'. Walk segments: leading integer segments are
        // dims; the first non-integer segment starts the dtype.
        let mut dims = Vec::new();
        let mut rest = inner;
        loop {
            // Take the prefix up to the next 'x'.
            match rest.split_once('x') {
                Some((head, tail)) => {
                    if let Ok(d) = head.trim().parse::<usize>() {
                        dims.push(d);
                        rest = tail;
                    } else {
                        // head is not an integer: the remainder (head + x +
                        // tail) is the dtype... but dtypes contain no 'x',
                        // so this must be an error unless it IS the dtype.
                        break;
                    }
                }
                None => break,
            }
        }
        let dtype_str = rest.trim();
        if dtype_str == "?" || dtype_str.contains('?') {
            bail!("dynamic dims unsupported: tensor<{inner}>");
        }
        let dtype = match DType::parse(dtype_str) {
            Some(d) => d,
            None => bail!("unknown element type '{dtype_str}' in tensor<{inner}>"),
        };
        Ok(TensorType { dims, dtype })
    }

    /// Parse a full type string like `tensor<128x256xbf16>`.
    pub fn parse(text: &str) -> Result<TensorType> {
        let t = text.trim();
        if let Some(stripped) = t.strip_prefix("tensor<") {
            if let Some(inner) = stripped.strip_suffix('>') {
                return Self::parse_inner(inner);
            }
        }
        bail!("not a tensor type: '{text}'")
    }
}

impl std::fmt::Display for TensorType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tensor<")?;
        for d in &self.dims {
            write!(f, "{d}x")?;
        }
        write!(f, "{}>", self.dtype)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ranked() {
        let t = TensorType::parse("tensor<128x256xbf16>").unwrap();
        assert_eq!(t.dims, vec![128, 256]);
        assert_eq!(t.dtype, DType::Bf16);
        assert_eq!(t.num_elements(), 128 * 256);
        assert_eq!(t.size_bytes(), 128 * 256 * 2);
    }

    #[test]
    fn parse_scalar() {
        let t = TensorType::parse("tensor<f32>").unwrap();
        assert_eq!(t.rank(), 0);
        assert_eq!(t.num_elements(), 1);
        assert_eq!(t.dtype, DType::F32);
    }

    #[test]
    fn parse_4d() {
        let t = TensorType::parse("tensor<1x3x32x32xbf16>").unwrap();
        assert_eq!(t.dims, vec![1, 3, 32, 32]);
    }

    #[test]
    fn parse_i1_and_ints() {
        assert_eq!(
            TensorType::parse("tensor<10xi1>").unwrap().dtype,
            DType::I1
        );
        assert_eq!(
            TensorType::parse("tensor<4xui32>").unwrap().dtype,
            DType::U32
        );
    }

    #[test]
    fn reject_dynamic_and_garbage() {
        assert!(TensorType::parse("tensor<?x4xf32>").is_err());
        assert!(TensorType::parse("tensor<4xunknown>").is_err());
        assert!(TensorType::parse("memref<4xf32>").is_err());
        assert!(TensorType::parse("tensor<>").is_err());
    }

    #[test]
    fn display_roundtrip() {
        for s in ["tensor<128x256xbf16>", "tensor<f32>", "tensor<1x1x1xi8>"] {
            let t = TensorType::parse(s).unwrap();
            assert_eq!(format!("{t}"), s);
        }
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::Bf16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::I64.bytes(), 8);
        assert!(DType::Bf16.is_float());
        assert!(!DType::I32.is_float());
    }
}
