//! Operation classification and conversion (the paper's §4.3).
//!
//! Parsed [`OpInfo`] records are classified by execution resource:
//!
//! * `dot_general` matching a matmul pattern → **Systolic GEMM** with
//!   derived (M, K, N) — routed to the validated SCALE-Sim model.
//! * `convolution` → **Systolic conv** — lowered to its im2col GEMM
//!   (plus a [`ConvLayer`] when it is a plain 2-D convolution).
//! * Elementwise arithmetic / comparison / transcendental ops → routed to
//!   the learned latency models.
//! * Shape/data-movement ops (reshape, transpose, broadcast, ...) →
//!   modeled as memory-bound byte movement.
//! * Compile-time ops (constant, iota) → zero cost.
//! * Anything else → `Unmodeled` (reported, conservatively costed as
//!   elementwise over the output).

use anyhow::{bail, Result};

use super::opinfo::{ConvDimLabel, OpInfo};
use super::types::TensorType;
use crate::scalesim::topology::{ConvLayer, GemmShape};

/// Elementwise operator kind (the learned models key on this).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    /// `add`
    Add,
    /// `subtract`
    Subtract,
    /// `multiply`
    Multiply,
    /// `divide`
    Divide,
    /// `maximum`
    Maximum,
    /// `minimum`
    Minimum,
    /// `exponential`
    Exp,
    /// `tanh`
    Tanh,
    /// `logistic` (sigmoid)
    Logistic,
    /// `rsqrt`
    Rsqrt,
    /// `sqrt`
    Sqrt,
    /// `log`
    Log,
    /// `negate`
    Negate,
    /// `abs`
    Abs,
    /// `compare`
    Compare,
    /// `select`
    Select,
    /// `convert` (dtype cast)
    Convert,
    /// `power`
    Power,
    /// Any other recognised elementwise op (proxied).
    Other,
}

impl EwKind {
    /// Map a short StableHLO op name to its elementwise kind.
    pub fn from_name(short: &str) -> Option<EwKind> {
        Some(match short {
            "add" => EwKind::Add,
            "subtract" => EwKind::Subtract,
            "multiply" => EwKind::Multiply,
            "divide" => EwKind::Divide,
            "maximum" => EwKind::Maximum,
            "minimum" => EwKind::Minimum,
            "exponential" => EwKind::Exp,
            "tanh" => EwKind::Tanh,
            "logistic" => EwKind::Logistic,
            "rsqrt" => EwKind::Rsqrt,
            "sqrt" => EwKind::Sqrt,
            "log" => EwKind::Log,
            "negate" => EwKind::Negate,
            "abs" => EwKind::Abs,
            "compare" => EwKind::Compare,
            "select" => EwKind::Select,
            "convert" => EwKind::Convert,
            "power" => EwKind::Power,
            "and" | "or" | "xor" | "not" | "sign" | "floor" | "ceil" | "round_nearest_afz"
            | "remainder" | "clamp" | "cosine" | "sine" | "atan2" | "cbrt" | "exponential_minus_one"
            | "log_plus_one" | "is_finite" => EwKind::Other,
            _ => return None,
        })
    }

    /// The canonical short name (learned-model key).
    pub fn name(&self) -> &'static str {
        match self {
            EwKind::Add => "add",
            EwKind::Subtract => "subtract",
            EwKind::Multiply => "multiply",
            EwKind::Divide => "divide",
            EwKind::Maximum => "maximum",
            EwKind::Minimum => "minimum",
            EwKind::Exp => "exponential",
            EwKind::Tanh => "tanh",
            EwKind::Logistic => "logistic",
            EwKind::Rsqrt => "rsqrt",
            EwKind::Sqrt => "sqrt",
            EwKind::Log => "log",
            EwKind::Negate => "negate",
            EwKind::Abs => "abs",
            EwKind::Compare => "compare",
            EwKind::Select => "select",
            EwKind::Convert => "convert",
            EwKind::Power => "power",
            EwKind::Other => "other",
        }
    }
}

/// Cross-chip collective communication kind (costed by the ICI model in
/// `crate::distributed`; zero-cost on a single chip).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// Sum across chips, full result everywhere.
    AllReduce,
    /// Concatenate shards across chips.
    AllGather,
    /// Sum then shard the result.
    ReduceScatter,
    /// Point-to-point shard exchange.
    CollectivePermute,
}

impl CollectiveKind {
    /// Map a short StableHLO op name to its collective kind.
    pub fn from_name(short: &str) -> Option<CollectiveKind> {
        Some(match short {
            "all_reduce" => CollectiveKind::AllReduce,
            "all_gather" => CollectiveKind::AllGather,
            "reduce_scatter" => CollectiveKind::ReduceScatter,
            "collective_permute" => CollectiveKind::CollectivePermute,
            _ => return None,
        })
    }

    /// The canonical short op name.
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::AllReduce => "all_reduce",
            CollectiveKind::AllGather => "all_gather",
            CollectiveKind::ReduceScatter => "reduce_scatter",
            CollectiveKind::CollectivePermute => "collective_permute",
        }
    }
}

impl std::fmt::Display for CollectiveKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Classification of one op.
#[derive(Debug, Clone, PartialEq)]
pub enum OpClass {
    /// Runs on the systolic array as `count` sequential GEMMs (count > 1
    /// for batched dot_general).
    SystolicGemm {
        /// Derived (M, K, N).
        gemm: GemmShape,
        /// Sequential GEMM repetitions (batch count).
        count: u64,
    },
    /// A 2-D convolution with full SCALE-Sim conv parameters.
    SystolicConv {
        /// Full convolution parameters.
        conv: ConvLayer,
        /// The im2col-lowered GEMM.
        gemm: GemmShape,
        /// Sequential repetitions (batch count).
        count: u64,
    },
    /// Elementwise op over `out` (routed to the learned model).
    Elementwise {
        /// The operator kind (learned-model key).
        kind: EwKind,
        /// Output tensor type.
        out: TensorType,
    },
    /// Reduction: contraction over `dimensions`; costed on input size.
    Reduction {
        /// Input tensor type.
        input: TensorType,
        /// Output tensor type.
        out: TensorType,
    },
    /// Pure data movement (reshape/transpose/broadcast/...).
    DataMovement {
        /// Bytes moved (output footprint).
        bytes: u64,
        /// Output tensor type.
        out: TensorType,
    },
    /// Cross-chip collective (`all_reduce`, `all_gather`, ...): free on a
    /// single chip, costed by the ICI model on a multi-chip slice.
    Collective {
        /// The collective kind.
        kind: CollectiveKind,
        /// Input payload bytes (the per-chip shard the op consumes).
        bytes_in: u64,
        /// Output tensor type.
        out: TensorType,
    },
    /// No runtime cost (constants, iota, metadata ops).
    Free,
    /// Not modeled; conservatively treated as elementwise on the output.
    Unmodeled {
        /// Why no model applies.
        reason: String,
        /// Output tensor type, when known.
        out: Option<TensorType>,
    },
}

/// Ops that move/relayout data without arithmetic.
const DATA_MOVEMENT_OPS: &[&str] = &[
    "reshape",
    "transpose",
    "broadcast_in_dim",
    "slice",
    "concatenate",
    "pad",
    "reverse",
    "gather",
    "scatter",
    "dynamic_slice",
    "dynamic_update_slice",
    "copy",
];

/// Ops with no runtime cost on the accelerator.
const FREE_OPS: &[&str] = &["constant", "iota", "return", "optimization_barrier", "tuple",
    "get_tuple_element", "after_all", "custom_call"];

/// Classify one op record.
pub fn classify(op: &OpInfo) -> OpClass {
    let short = op.short_name();

    if short == "dot_general" || short == "dot" {
        return match dot_to_gemm(op) {
            Ok((gemm, count)) => OpClass::SystolicGemm { gemm, count },
            Err(e) => OpClass::Unmodeled {
                reason: format!("dot_general not matmul-like: {e}"),
                out: op.out_type().cloned(),
            },
        };
    }

    if short == "convolution" {
        return match conv_to_gemm(op) {
            Ok((conv, gemm, count)) => OpClass::SystolicConv { conv, gemm, count },
            Err(e) => OpClass::Unmodeled {
                reason: format!("convolution not supported: {e}"),
                out: op.out_type().cloned(),
            },
        };
    }

    if let Some(kind) = CollectiveKind::from_name(short) {
        if let (Some(input), Some(out)) = (op.operand_types.first(), op.out_type()) {
            return OpClass::Collective {
                kind,
                bytes_in: input.size_bytes(),
                out: out.clone(),
            };
        }
        return OpClass::Unmodeled {
            reason: format!("collective '{short}' missing operand/result types"),
            out: op.out_type().cloned(),
        };
    }

    if let Some(kind) = EwKind::from_name(short) {
        if let Some(out) = op.out_type() {
            return OpClass::Elementwise {
                kind,
                out: out.clone(),
            };
        }
    }

    if short == "reduce" || short == "reduce_window" {
        if let (Some(input), Some(out)) = (op.operand_types.first(), op.out_type()) {
            return OpClass::Reduction {
                input: input.clone(),
                out: out.clone(),
            };
        }
    }

    if DATA_MOVEMENT_OPS.contains(&short) {
        if let Some(out) = op.out_type() {
            return OpClass::DataMovement {
                bytes: out.size_bytes(),
                out: out.clone(),
            };
        }
    }

    if FREE_OPS.contains(&short) {
        return OpClass::Free;
    }

    OpClass::Unmodeled {
        reason: format!("op '{}' has no performance model", op.op_name),
        out: op.out_type().cloned(),
    }
}

/// Derive (GEMM, batch-count) from a dot_general.
///
/// Batch dims multiply into a GEMM *count*; remaining lhs free dims fold
/// into M, contracting dims into K, rhs free dims into N. This matches how
/// the TPU compiler lowers batched matmuls onto the MXU (one GEMM per
/// batch element, or fused — either way the MAC count is identical).
pub fn dot_to_gemm(op: &OpInfo) -> Result<(GemmShape, u64)> {
    let Some(dims) = &op.dot_dims else {
        // Plain `dot`: operand ranks decide.
        let (a, b) = two_operand_types(op)?;
        return match (a.rank(), b.rank()) {
            (2, 2) => Ok((GemmShape::new(a.dims[0], a.dims[1], b.dims[1]), 1)),
            (1, 2) => Ok((GemmShape::new(1, a.dims[0], b.dims[1]), 1)),
            (2, 1) => Ok((GemmShape::new(a.dims[0], a.dims[1], 1), 1)),
            _ => bail!("dot with ranks {}x{}", a.rank(), b.rank()),
        };
    };
    let dims = dims.clone();
    let (a, b) = two_operand_types(op)?;

    if dims.lhs_contract.len() != dims.rhs_contract.len() {
        bail!("mismatched contracting dim counts");
    }
    if dims.lhs_batch.len() != dims.rhs_batch.len() {
        bail!("mismatched batch dim counts");
    }

    let mut count: u64 = 1;
    for (&lb, &rb) in dims.lhs_batch.iter().zip(&dims.rhs_batch) {
        let (dl, dr) = (dim_at(a, lb)?, dim_at(b, rb)?);
        if dl != dr {
            bail!("batch dim mismatch {dl} vs {dr}");
        }
        count *= dl as u64;
    }

    let mut k: usize = 1;
    for (&lc, &rc) in dims.lhs_contract.iter().zip(&dims.rhs_contract) {
        let (dl, dr) = (dim_at(a, lc)?, dim_at(b, rc)?);
        if dl != dr {
            bail!("contracting dim mismatch {dl} vs {dr}");
        }
        k *= dl;
    }

    let m: usize = free_dims_product(a, &dims.lhs_batch, &dims.lhs_contract)?;
    let n: usize = free_dims_product(b, &dims.rhs_batch, &dims.rhs_contract)?;
    let gemm = GemmShape::new(m.max(1), k.max(1), n.max(1));
    Ok((gemm, count.max(1)))
}

fn two_operand_types(op: &OpInfo) -> Result<(&TensorType, &TensorType)> {
    if op.operand_types.len() < 2 {
        bail!("missing operand types");
    }
    Ok((&op.operand_types[0], &op.operand_types[1]))
}

fn dim_at(t: &TensorType, i: usize) -> Result<usize> {
    t.dims
        .get(i)
        .copied()
        .ok_or_else(|| anyhow::anyhow!("dim index {i} out of range for {t}"))
}

fn free_dims_product(t: &TensorType, batch: &[usize], contract: &[usize]) -> Result<usize> {
    let mut p = 1usize;
    for (i, &d) in t.dims.iter().enumerate() {
        if !batch.contains(&i) && !contract.contains(&i) {
            p = p
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("dim product overflow"))?;
        }
    }
    Ok(p)
}

/// Derive (ConvLayer, im2col GEMM, batch-count) from a convolution op.
///
/// The GEMM is computed from the *result* spatial dims (so padding,
/// dilation and strides are already folded in, exactly as the compiler
/// sees them) and the kernel shape:
///
///   M = ∏ output spatial dims (per batch element)
///   K = ∏ kernel spatial dims × (in_channels / feature_groups)
///   N = out_channels
pub fn conv_to_gemm(op: &OpInfo) -> Result<(ConvLayer, GemmShape, u64)> {
    let Some(attrs) = &op.conv_attrs else {
        bail!("missing convolution attributes")
    };
    let (input, kernel) = two_operand_types(op)?;
    let Some(output) = op.out_type() else {
        bail!("missing result type")
    };

    if attrs.input_layout.len() != input.rank()
        || attrs.kernel_layout.len() != kernel.rank()
        || attrs.output_layout.len() != output.rank()
    {
        bail!("dim_numbers rank mismatch");
    }

    let find = |layout: &[ConvDimLabel], want: ConvDimLabel| -> Option<usize> {
        layout.iter().position(|&l| l == want)
    };
    let spatial_positions = |layout: &[ConvDimLabel]| -> Vec<(usize, usize)> {
        // (spatial index, tensor dim position), sorted by spatial index.
        let mut v: Vec<(usize, usize)> = layout
            .iter()
            .enumerate()
            .filter_map(|(pos, l)| match l {
                ConvDimLabel::Spatial(s) => Some((*s, pos)),
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v
    };

    let batch_pos = find(&attrs.input_layout, ConvDimLabel::Batch)
        .ok_or_else(|| anyhow::anyhow!("no batch dim in input layout"))?;
    let in_feat_pos = find(&attrs.input_layout, ConvDimLabel::Feature)
        .ok_or_else(|| anyhow::anyhow!("no feature dim in input layout"))?;
    let k_in_pos = find(&attrs.kernel_layout, ConvDimLabel::KernelIn)
        .ok_or_else(|| anyhow::anyhow!("no 'i' dim in kernel layout"))?;
    let k_out_pos = find(&attrs.kernel_layout, ConvDimLabel::KernelOut)
        .ok_or_else(|| anyhow::anyhow!("no 'o' dim in kernel layout"))?;
    let out_feat_pos = find(&attrs.output_layout, ConvDimLabel::Feature)
        .ok_or_else(|| anyhow::anyhow!("no feature dim in output layout"))?;

    let batch = input.dims[batch_pos];
    let in_channels = input.dims[in_feat_pos];
    let out_channels = output.dims[out_feat_pos];
    let kernel_in = kernel.dims[k_in_pos];
    let _ = kernel.dims[k_out_pos];

    let in_spatial: Vec<usize> = spatial_positions(&attrs.input_layout)
        .iter()
        .map(|&(_, p)| input.dims[p])
        .collect();
    let kernel_spatial: Vec<usize> = spatial_positions(&attrs.kernel_layout)
        .iter()
        .map(|&(_, p)| kernel.dims[p])
        .collect();
    let out_spatial: Vec<usize> = spatial_positions(&attrs.output_layout)
        .iter()
        .map(|&(_, p)| output.dims[p])
        .collect();

    let feature_groups = attrs.feature_group_count.max(1);
    if in_channels % feature_groups != 0 {
        bail!("in_channels {in_channels} not divisible by feature groups {feature_groups}");
    }

    let m: usize = out_spatial.iter().product();
    let k: usize = kernel_spatial.iter().product::<usize>() * (in_channels / feature_groups);
    let n = out_channels;
    let gemm = GemmShape::new(m.max(1), k.max(1), n.max(1));

    // A ConvLayer is only well-formed for 2-D spatial convs; fabricate a
    // 1x-size dimension for 1-D convs so SCALE-Sim's conv interface works.
    let get2 = |v: &[usize]| -> (usize, usize) {
        match v.len() {
            0 => (1, 1),
            1 => (v[0], 1),
            _ => (v[0], v[1]),
        }
    };
    let (ih, iw) = get2(&in_spatial);
    let (fh, fw) = get2(&kernel_spatial);
    let (sh, sw) = get2(&attrs.strides);
    let conv = ConvLayer {
        name: format!("conv_{}", op.index),
        ifmap_h: ih,
        ifmap_w: iw,
        filter_h: fh.min(ih),
        filter_w: fw.min(iw),
        channels: in_channels / feature_groups,
        num_filters: out_channels,
        stride_h: sh.max(1),
        stride_w: sw.max(1),
    };

    let _ = kernel_in;
    Ok((conv, gemm, batch.max(1) as u64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parser::parse_module;

    fn first_op_class(text: &str) -> OpClass {
        let m = parse_module(text).unwrap();
        classify(&m.entry().unwrap().ops[0])
    }

    #[test]
    fn classify_matmul() {
        let text = r#"
module { func.func @main(%a: tensor<128x256xbf16>, %b: tensor<256x512xbf16>) -> tensor<128x512xbf16> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<128x256xbf16>, tensor<256x512xbf16>) -> tensor<128x512xbf16>
  return %0 : tensor<128x512xbf16>
} }"#;
        match first_op_class(text) {
            OpClass::SystolicGemm { gemm, count } => {
                assert_eq!(gemm, GemmShape::new(128, 256, 512));
                assert_eq!(count, 1);
            }
            other => panic!("expected gemm, got {other:?}"),
        }
    }

    #[test]
    fn classify_batched_matmul() {
        let text = r#"
module { func.func @main(%a: tensor<8x64x32xf32>, %b: tensor<8x32x16xf32>) -> tensor<8x64x16xf32> {
  %0 = stablehlo.dot_general %a, %b, batching_dims = [0] x [0], contracting_dims = [2] x [1] : (tensor<8x64x32xf32>, tensor<8x32x16xf32>) -> tensor<8x64x16xf32>
  return %0 : tensor<8x64x16xf32>
} }"#;
        match first_op_class(text) {
            OpClass::SystolicGemm { gemm, count } => {
                assert_eq!(gemm, GemmShape::new(64, 32, 16));
                assert_eq!(count, 8);
            }
            other => panic!("expected gemm, got {other:?}"),
        }
    }

    #[test]
    fn classify_conv() {
        let text = r#"
module { func.func @main(%x: tensor<1x3x32x32xbf16>, %w: tensor<16x3x3x3xbf16>) -> tensor<1x16x16x16xbf16> {
  %0 = stablehlo.convolution(%x, %w) dim_numbers = [b, f, 0, 1]x[o, i, 0, 1]->[b, f, 0, 1], window = {stride = [2, 2], pad = [[0, 1], [0, 1]], lhs_dilate = [1, 1], rhs_dilate = [1, 1], reverse = [false, false]} {batch_group_count = 1 : i64, feature_group_count = 1 : i64} : (tensor<1x3x32x32xbf16>, tensor<16x3x3x3xbf16>) -> tensor<1x16x16x16xbf16>
  return %0 : tensor<1x16x16x16xbf16>
} }"#;
        match first_op_class(text) {
            OpClass::SystolicConv { conv, gemm, count } => {
                assert_eq!(gemm, GemmShape::new(16 * 16, 3 * 3 * 3, 16));
                assert_eq!(count, 1);
                assert_eq!(conv.channels, 3);
                assert_eq!(conv.num_filters, 16);
                assert_eq!(conv.stride_h, 2);
            }
            other => panic!("expected conv, got {other:?}"),
        }
    }

    #[test]
    fn classify_elementwise_kinds() {
        for (opname, kind) in [
            ("stablehlo.add", EwKind::Add),
            ("stablehlo.multiply", EwKind::Multiply),
            ("stablehlo.maximum", EwKind::Maximum),
            ("stablehlo.exponential", EwKind::Exp),
        ] {
            let text = format!(
                r#"
module {{ func.func @main(%a: tensor<64x64xbf16>) -> tensor<64x64xbf16> {{
  %0 = {opname} %a, %a : tensor<64x64xbf16>
  return %0 : tensor<64x64xbf16>
}} }}"#
            );
            match first_op_class(&text) {
                OpClass::Elementwise { kind: k, out } => {
                    assert_eq!(k, kind);
                    assert_eq!(out.num_elements(), 4096);
                }
                other => panic!("expected elementwise, got {other:?}"),
            }
        }
    }

    #[test]
    fn classify_free_and_movement() {
        let text = r#"
module { func.func @main(%a: tensor<4x8xf32>) -> tensor<8x4xf32> {
  %0 = stablehlo.transpose %a, dims = [1, 0] : (tensor<4x8xf32>) -> tensor<8x4xf32>
  return %0 : tensor<8x4xf32>
} }"#;
        match first_op_class(text) {
            OpClass::DataMovement { bytes, .. } => assert_eq!(bytes, 32 * 4),
            other => panic!("expected data movement, got {other:?}"),
        }

        let text2 = r#"
module { func.func @main() -> tensor<f32> {
  %cst = stablehlo.constant dense<1.0> : tensor<f32>
  return %cst : tensor<f32>
} }"#;
        assert_eq!(first_op_class(text2), OpClass::Free);
    }

    #[test]
    fn classify_reduction() {
        let text = r#"
module { func.func @main(%a: tensor<8x128xf32>) -> tensor<8xf32> {
  %cst = stablehlo.constant dense<0.000000e+00> : tensor<f32>
  %0 = stablehlo.reduce(%a init: %cst) applies stablehlo.add across dimensions = [1] : (tensor<8x128xf32>, tensor<f32>) -> tensor<8xf32>
  return %0 : tensor<8xf32>
} }"#;
        let m = parse_module(text).unwrap();
        match classify(&m.entry().unwrap().ops[1]) {
            OpClass::Reduction { input, out } => {
                assert_eq!(input.num_elements(), 1024);
                assert_eq!(out.num_elements(), 8);
            }
            other => panic!("expected reduction, got {other:?}"),
        }
    }

    #[test]
    fn unmodeled_has_reason() {
        let text = r#"
module { func.func @main(%a: tensor<4xf32>) -> tensor<4xf32> {
  %0 = stablehlo.cholesky %a : tensor<4xf32>
  return %0 : tensor<4xf32>
} }"#;
        match first_op_class(text) {
            OpClass::Unmodeled { reason, out } => {
                assert!(reason.contains("cholesky"));
                assert!(out.is_some());
            }
            other => panic!("expected unmodeled, got {other:?}"),
        }
    }

    #[test]
    fn classify_collectives() {
        let text = r#"
module { func.func @main(%a: tensor<256x1024xf32>) -> tensor<1024x1024xf32> {
  %0 = "stablehlo.all_gather"(%a) {all_gather_dim = 0 : i64, replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>} : (tensor<256x1024xf32>) -> tensor<1024x1024xf32>
  return %0 : tensor<1024x1024xf32>
} }"#;
        match first_op_class(text) {
            OpClass::Collective { kind, bytes_in, out } => {
                assert_eq!(kind, CollectiveKind::AllGather);
                assert_eq!(bytes_in, 256 * 1024 * 4);
                assert_eq!(out.size_bytes(), 1024 * 1024 * 4);
            }
            other => panic!("expected collective, got {other:?}"),
        }
        for (name, kind) in [
            ("all_reduce", CollectiveKind::AllReduce),
            ("reduce_scatter", CollectiveKind::ReduceScatter),
            ("collective_permute", CollectiveKind::CollectivePermute),
        ] {
            assert_eq!(CollectiveKind::from_name(name), Some(kind));
            assert_eq!(kind.name(), name);
        }
    }

    #[test]
    fn vector_matrix_dot() {
        let text = r#"
module { func.func @main(%a: tensor<256xf32>, %b: tensor<256x512xf32>) -> tensor<512xf32> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [0] x [0] : (tensor<256xf32>, tensor<256x512xf32>) -> tensor<512xf32>
  return %0 : tensor<512xf32>
} }"#;
        match first_op_class(text) {
            OpClass::SystolicGemm { gemm, count } => {
                assert_eq!(gemm, GemmShape::new(1, 256, 512));
                assert_eq!(count, 1);
            }
            other => panic!("expected gemm, got {other:?}"),
        }
    }
}
