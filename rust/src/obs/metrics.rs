//! Atomic metrics primitives and the registry that names them.
//!
//! Three instrument kinds, all lock-free on the record path:
//!
//! * [`Counter`] — a monotonically increasing `u64`.
//! * [`Gauge`] — a signed instantaneous value (queue depths, occupancy).
//! * [`Histogram`] — fixed log2-bucket latency histogram with *exact*
//!   counts: every observation lands in the bucket `[2^k, 2^(k+1))`
//!   holding its value, plus dedicated underflow/overflow buckets. No
//!   sampling, no decay — snapshots are exact sums of what was recorded.
//!
//! The [`Registry`] hands out `Arc` handles keyed by
//! `(family, sorted label set)`; callers cache the handle and record
//! through plain atomics, so the registry lock is only taken at
//! registration and snapshot time.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// A counter at zero.
    pub fn new() -> Counter {
        Counter::default()
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Raise the counter to `total` if it is currently below it. Used to
    /// mirror an externally maintained monotonic total (e.g. the shape
    /// cache's per-shard atomics) into the registry at scrape time.
    pub fn observe_total(&self, total: u64) {
        self.value.fetch_max(total, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// An instantaneous signed value.
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    /// A gauge at zero.
    pub fn new() -> Gauge {
        Gauge::default()
    }

    /// Set the gauge to an absolute value.
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Add `n` (may be negative).
    pub fn add(&self, n: i64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Decrement by one.
    pub fn dec(&self) {
        self.add(-1);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed log2-bucket histogram with exact counts.
///
/// Bucket layout for `new(min_exp, max_exp)`:
///
/// * bucket `0` — underflow, values in `[0, 2^min_exp)`;
/// * bucket `i` for `1 <= i <= max_exp - min_exp` — values in
///   `[2^(min_exp+i-1), 2^(min_exp+i))`;
/// * the last bucket — overflow, values in `[2^max_exp, u64::MAX]`.
///
/// The serve defaults (`min_exp = 10`, `max_exp = 34`) cover 1 µs to
/// ~17 s at nanosecond inputs in 26 buckets.
#[derive(Debug)]
pub struct Histogram {
    min_exp: u32,
    max_exp: u32,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    /// A histogram whose finite buckets span `[2^min_exp, 2^max_exp)`.
    ///
    /// Requires `min_exp < max_exp < 64`.
    pub fn new(min_exp: u32, max_exp: u32) -> Histogram {
        assert!(min_exp < max_exp && max_exp < 64, "bad histogram range");
        let n = (max_exp - min_exp) as usize + 2;
        Histogram {
            min_exp,
            max_exp,
            buckets: (0..n).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// The serve-path default: nanosecond observations from 1 µs
    /// (`2^10` ns) to ~17 s (`2^34` ns).
    pub fn for_latency_ns() -> Histogram {
        Histogram::new(10, 34)
    }

    fn bucket_index(&self, value: u64) -> usize {
        if value < (1u64 << self.min_exp) {
            return 0;
        }
        // value >= 2^min_exp >= 1, so leading_zeros < 64.
        let k = 63 - value.leading_zeros();
        if k >= self.max_exp {
            self.buckets.len() - 1
        } else {
            (k - self.min_exp) as usize + 1
        }
    }

    /// Record one observation.
    pub fn record(&self, value: u64) {
        self.buckets[self.bucket_index(value)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// A consistent-enough point-in-time copy (buckets are read
    /// individually; concurrent writers may skew `count` by in-flight
    /// observations, which is fine for monitoring).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            min_exp: self.min_exp,
            max_exp: self.max_exp,
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// An owned point-in-time copy of a [`Histogram`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Exponent of the smallest finite bucket boundary.
    pub min_exp: u32,
    /// Exponent of the overflow boundary.
    pub max_exp: u32,
    /// Per-bucket counts: underflow, finite buckets, overflow.
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values.
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Exclusive upper bound of bucket `i`; `None` for the overflow
    /// bucket.
    pub fn bucket_bound(&self, i: usize) -> Option<u64> {
        if i + 1 >= self.buckets.len() {
            None
        } else {
            Some(1u64 << (self.min_exp + i as u32))
        }
    }

    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile at log2 resolution: the upper bound of the
    /// bucket holding the `q`-th observation (the overflow bucket
    /// reports its lower bound `2^max_exp`). `q` is clamped to `[0, 1]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return match self.bucket_bound(i) {
                    Some(bound) => bound as f64,
                    None => (1u64 << self.max_exp) as f64,
                };
            }
        }
        (1u64 << self.max_exp) as f64
    }

    /// Fold another snapshot into this one bucketwise. Fails if the
    /// bucket layouts differ.
    pub fn merge(&mut self, other: &HistogramSnapshot) -> Result<()> {
        if self.min_exp != other.min_exp || self.max_exp != other.max_exp {
            bail!(
                "histogram layout mismatch: [{}, {}] vs [{}, {}]",
                self.min_exp,
                self.max_exp,
                other.min_exp,
                other.max_exp
            );
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        Ok(())
    }

    /// The snapshot as a JSON object.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("min_exp", Json::Num(self.min_exp as f64))
            .set("max_exp", Json::Num(self.max_exp as f64))
            .set(
                "buckets",
                Json::Arr(self.buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
            )
            .set("count", Json::Num(self.count as f64))
            .set("sum", Json::Num(self.sum as f64));
        j
    }

    /// Parse a snapshot back from [`HistogramSnapshot::to_json`] output.
    pub fn from_json(j: &Json) -> Result<HistogramSnapshot> {
        let min_exp = j.req_usize("min_exp")? as u32;
        let max_exp = j.req_usize("max_exp")? as u32;
        let buckets: Vec<u64> = j
            .req_arr("buckets")?
            .iter()
            .map(|b| b.as_f64().map(|v| v as u64).context("bucket not a number"))
            .collect::<Result<_>>()?;
        if buckets.len() != (max_exp.saturating_sub(min_exp)) as usize + 2 {
            bail!("bucket count {} does not match layout", buckets.len());
        }
        Ok(HistogramSnapshot {
            min_exp,
            max_exp,
            buckets,
            count: j.req_f64("count")? as u64,
            sum: j.req_f64("sum")? as u64,
        })
    }
}

/// A `(family, sorted labels)` metric identity.
type MetricId = (String, Vec<(String, String)>);

fn metric_id(family: &str, labels: &[(&str, &str)]) -> MetricId {
    let mut l: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect();
    l.sort();
    (family.to_string(), l)
}

#[derive(Debug, Default)]
struct RegistryInner {
    help: BTreeMap<String, String>,
    counters: BTreeMap<MetricId, Arc<Counter>>,
    gauges: BTreeMap<MetricId, Arc<Gauge>>,
    histograms: BTreeMap<MetricId, Arc<Histogram>>,
}

/// Named metric registry: get-or-create instruments by
/// `(family, labels)` and snapshot everything for export.
///
/// The lock guards only registration and snapshots; recording goes
/// through the returned `Arc` handles without touching the registry.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Attach help text to a metric family (rendered as `# HELP`).
    pub fn set_help(&self, family: &str, help: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.help.insert(family.to_string(), help.to_string());
    }

    /// Get or create the counter for `(family, labels)`.
    pub fn counter(&self, family: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let mut inner = self.inner.lock().unwrap();
        Arc::clone(
            inner
                .counters
                .entry(metric_id(family, labels))
                .or_insert_with(|| Arc::new(Counter::new())),
        )
    }

    /// Get or create the gauge for `(family, labels)`.
    pub fn gauge(&self, family: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let mut inner = self.inner.lock().unwrap();
        Arc::clone(
            inner
                .gauges
                .entry(metric_id(family, labels))
                .or_insert_with(|| Arc::new(Gauge::new())),
        )
    }

    /// Get or create the histogram for `(family, labels)`. The bucket
    /// layout is fixed by the first registration; later calls with the
    /// same identity return the existing instrument.
    pub fn histogram(
        &self,
        family: &str,
        labels: &[(&str, &str)],
        min_exp: u32,
        max_exp: u32,
    ) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap();
        Arc::clone(
            inner
                .histograms
                .entry(metric_id(family, labels))
                .or_insert_with(|| Arc::new(Histogram::new(min_exp, max_exp))),
        )
    }

    /// Snapshot every registered instrument.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let inner = self.inner.lock().unwrap();
        RegistrySnapshot {
            help: inner.help.clone(),
            counters: inner
                .counters
                .iter()
                .map(|((f, l), c)| (f.clone(), l.clone(), c.get()))
                .collect(),
            gauges: inner
                .gauges
                .iter()
                .map(|((f, l), g)| (f.clone(), l.clone(), g.get()))
                .collect(),
            histograms: inner
                .histograms
                .iter()
                .map(|((f, l), h)| (f.clone(), l.clone(), h.snapshot()))
                .collect(),
        }
    }
}

/// Owned snapshot of a whole [`Registry`], ordered by
/// `(family, labels)`. The unit the exporters and the merge/round-trip
/// machinery operate on.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegistrySnapshot {
    /// `# HELP` text per family.
    pub help: BTreeMap<String, String>,
    /// `(family, labels, value)` per counter.
    pub counters: Vec<(String, Vec<(String, String)>, u64)>,
    /// `(family, labels, value)` per gauge.
    pub gauges: Vec<(String, Vec<(String, String)>, i64)>,
    /// `(family, labels, snapshot)` per histogram.
    pub histograms: Vec<(String, Vec<(String, String)>, HistogramSnapshot)>,
}

fn labels_to_json(labels: &[(String, String)]) -> Json {
    let mut o = Json::obj();
    for (k, v) in labels {
        o.set(k, Json::Str(v.clone()));
    }
    o
}

fn labels_from_json(j: &Json) -> Result<Vec<(String, String)>> {
    let Json::Obj(map) = j else {
        bail!("labels must be an object");
    };
    let mut out = Vec::with_capacity(map.len());
    for (k, v) in map {
        let Json::Str(s) = v else {
            bail!("label value for '{k}' must be a string");
        };
        out.push((k.clone(), s.clone()));
    }
    Ok(out)
}

impl RegistrySnapshot {
    /// Merge another snapshot into this one: counters and histograms
    /// add (matched by `(family, labels)`, unmatched entries append);
    /// a matched gauge takes the other side's instantaneous value.
    pub fn merge(&mut self, other: &RegistrySnapshot) -> Result<()> {
        for (f, l, v) in &other.counters {
            match self
                .counters
                .iter_mut()
                .find(|(sf, sl, _)| sf == f && sl == l)
            {
                Some((_, _, sv)) => *sv += v,
                None => self.counters.push((f.clone(), l.clone(), *v)),
            }
        }
        for (f, l, v) in &other.gauges {
            match self.gauges.iter_mut().find(|(sf, sl, _)| sf == f && sl == l) {
                Some((_, _, sv)) => *sv = *v,
                None => self.gauges.push((f.clone(), l.clone(), *v)),
            }
        }
        for (f, l, h) in &other.histograms {
            match self
                .histograms
                .iter_mut()
                .find(|(sf, sl, _)| sf == f && sl == l)
            {
                Some((_, _, sh)) => sh.merge(h).with_context(|| format!("merging '{f}'"))?,
                None => self.histograms.push((f.clone(), l.clone(), h.clone())),
            }
        }
        for (f, h) in &other.help {
            self.help.entry(f.clone()).or_insert_with(|| h.clone());
        }
        Ok(())
    }

    /// The snapshot as one JSON object (the `{"type":"metrics"}` serve
    /// response payload).
    pub fn to_json(&self) -> Json {
        let counters: Vec<Json> = self
            .counters
            .iter()
            .map(|(f, l, v)| {
                let mut o = Json::obj();
                o.set("family", Json::Str(f.clone()))
                    .set("labels", labels_to_json(l))
                    .set("value", Json::Num(*v as f64));
                o
            })
            .collect();
        let gauges: Vec<Json> = self
            .gauges
            .iter()
            .map(|(f, l, v)| {
                let mut o = Json::obj();
                o.set("family", Json::Str(f.clone()))
                    .set("labels", labels_to_json(l))
                    .set("value", Json::Num(*v as f64));
                o
            })
            .collect();
        let histograms: Vec<Json> = self
            .histograms
            .iter()
            .map(|(f, l, h)| {
                let mut o = Json::obj();
                o.set("family", Json::Str(f.clone()))
                    .set("labels", labels_to_json(l))
                    .set("histogram", h.to_json());
                o
            })
            .collect();
        let mut help = Json::obj();
        for (k, v) in &self.help {
            help.set(k, Json::Str(v.clone()));
        }
        let mut j = Json::obj();
        j.set("counters", Json::Arr(counters))
            .set("gauges", Json::Arr(gauges))
            .set("histograms", Json::Arr(histograms))
            .set("help", help);
        j
    }

    /// Parse a snapshot back from [`RegistrySnapshot::to_json`] output.
    pub fn from_json(j: &Json) -> Result<RegistrySnapshot> {
        let mut snap = RegistrySnapshot::default();
        for c in j.req_arr("counters")? {
            snap.counters.push((
                c.req_str("family")?.to_string(),
                labels_from_json(c.get("labels").context("missing labels")?)?,
                c.req_f64("value")? as u64,
            ));
        }
        for g in j.req_arr("gauges")? {
            snap.gauges.push((
                g.req_str("family")?.to_string(),
                labels_from_json(g.get("labels").context("missing labels")?)?,
                g.req_f64("value")? as i64,
            ));
        }
        for h in j.req_arr("histograms")? {
            snap.histograms.push((
                h.req_str("family")?.to_string(),
                labels_from_json(h.get("labels").context("missing labels")?)?,
                HistogramSnapshot::from_json(h.get("histogram").context("missing histogram")?)?,
            ));
        }
        if let Some(Json::Obj(help)) = j.get("help") {
            for (k, v) in help {
                if let Json::Str(s) = v {
                    snap.help.insert(k.clone(), s.clone());
                }
            }
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.observe_total(3); // below: no-op
        assert_eq!(c.get(), 5);
        c.observe_total(9);
        assert_eq!(c.get(), 9);
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.add(-5);
        assert_eq!(g.get(), -4);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn histogram_buckets_exact_powers_of_two() {
        let h = Histogram::new(4, 8); // finite span [16, 256)
        assert_eq!(h.buckets.len(), 6);
        // Underflow: [0, 16).
        assert_eq!(h.bucket_index(0), 0);
        assert_eq!(h.bucket_index(15), 0);
        // Exact lower boundary lands in the bucket it opens.
        assert_eq!(h.bucket_index(16), 1);
        assert_eq!(h.bucket_index(31), 1);
        assert_eq!(h.bucket_index(32), 2);
        assert_eq!(h.bucket_index(64), 3);
        assert_eq!(h.bucket_index(128), 4);
        assert_eq!(h.bucket_index(255), 4);
        // Overflow: [256, ..].
        assert_eq!(h.bucket_index(256), 5);
        assert_eq!(h.bucket_index(u64::MAX), 5);
    }

    #[test]
    fn histogram_snapshot_and_quantiles() {
        let h = Histogram::new(4, 8);
        for v in [1u64, 16, 17, 40, 300] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 374);
        assert_eq!(s.buckets, vec![1, 2, 1, 0, 0, 1]);
        assert_eq!(s.bucket_bound(0), Some(16));
        assert_eq!(s.bucket_bound(4), Some(256));
        assert_eq!(s.bucket_bound(5), None);
        // Median observation (rank 3) sits in bucket [16, 32).
        assert_eq!(s.quantile(0.5), 32.0);
        // The max lives in the overflow bucket, reported at 2^max_exp.
        assert_eq!(s.quantile(1.0), 256.0);
        assert!((s.mean() - 74.8).abs() < 1e-9);
        let empty = Histogram::new(4, 8).snapshot();
        assert_eq!(empty.quantile(0.99), 0.0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn histogram_merge_and_json_round_trip() {
        let a = Histogram::new(4, 8);
        a.record(20);
        a.record(1000);
        let b = Histogram::new(4, 8);
        b.record(5);
        b.record(20);
        let mut sa = a.snapshot();
        sa.merge(&b.snapshot()).unwrap();
        assert_eq!(sa.count, 4);
        assert_eq!(sa.buckets, vec![1, 2, 0, 0, 0, 1]);
        let round = HistogramSnapshot::from_json(&sa.to_json()).unwrap();
        assert_eq!(round, sa);
        let other = Histogram::new(2, 8);
        assert!(sa.merge(&other.snapshot()).is_err());
    }

    #[test]
    fn registry_hands_out_shared_instruments() {
        let r = Registry::new();
        let c1 = r.counter("req_total", &[("type", "gemm")]);
        let c2 = r.counter("req_total", &[("type", "gemm")]);
        c1.inc();
        c2.inc();
        assert_eq!(c1.get(), 2);
        let other = r.counter("req_total", &[("type", "module")]);
        assert_eq!(other.get(), 0);
        let h = r.histogram("lat_ns", &[], 10, 34);
        h.record(2048);
        let snap = r.snapshot();
        assert_eq!(snap.counters.len(), 2);
        assert_eq!(snap.histograms.len(), 1);
        assert_eq!(snap.histograms[0].2.count, 1);
    }

    #[test]
    fn registry_snapshot_merge_and_round_trip() {
        let r = Registry::new();
        r.set_help("req_total", "requests served");
        r.counter("req_total", &[("type", "gemm")]).add(3);
        r.gauge("depth", &[]).set(5);
        r.histogram("lat_ns", &[], 10, 34).record(4096);
        let mut a = r.snapshot();
        let b = r.snapshot();
        a.merge(&b).unwrap();
        assert_eq!(a.counters[0].2, 6);
        assert_eq!(a.gauges[0].2, 5);
        assert_eq!(a.histograms[0].2.count, 2);
        let round = RegistrySnapshot::from_json(&b.to_json()).unwrap();
        assert_eq!(round, b);
    }
}
