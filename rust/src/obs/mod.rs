//! Dependency-free observability: metrics, spans, and exporters.
//!
//! The subsystem is deliberately self-contained (std + the crate's own
//! JSON) and serving-agnostic — nothing here knows about estimators or
//! sockets. It provides:
//!
//! * [`clock`] — the injectable [`Clock`] trait: [`MonotonicClock`] for
//!   production, [`LogicalClock`] for deterministic tests.
//! * [`metrics`] — atomic [`Counter`]s, [`Gauge`]s, and exact-count
//!   fixed-log2-bucket [`Histogram`]s behind a name-keyed [`Registry`].
//! * [`trace`] — the Chrome trace-event model ([`TraceEvent`]), the
//!   guard-based [`SpanRecorder`], and the streaming
//!   [`TraceFileWriter`].
//! * [`export`] — [`render_prometheus`] text exposition and the
//!   [`MetricsScrape`] plaintext endpoint.
//!
//! The serving stack wires these together in
//! [`crate::coordinator::service::ServeMetrics`]; the scheduler's
//! trace renderers live next to the schedules they export
//! ([`crate::graph::ModuleSchedule::trace_events`] and friends).

pub mod clock;
pub mod export;
pub mod metrics;
pub mod trace;

pub use clock::{Clock, LogicalClock, MonotonicClock};
pub use export::{render_prometheus, MetricsScrape};
pub use metrics::{Counter, Gauge, Histogram, HistogramSnapshot, Registry, RegistrySnapshot};
pub use trace::{trace_json, SpanGuard, SpanRecorder, TraceEvent, TraceFileWriter};
