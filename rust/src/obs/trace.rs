//! Chrome trace-event model, the span recorder, and the streaming
//! trace-file writer.
//!
//! Events follow the Trace Event Format consumed by Perfetto and
//! `chrome://tracing`: complete (`"ph":"X"`) events carry a start
//! timestamp and duration in microseconds; metadata (`"ph":"M"`) events
//! name processes and threads. Viewers nest `X` events on the same
//! `(pid, tid)` lane by time containment, which is how request phase
//! spans render as children of their request span.

use std::fs;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

use super::clock::Clock;

/// One trace event in the Chrome trace-event format.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Event name (the label rendered on the slice).
    pub name: String,
    /// Comma-separated category list.
    pub cat: String,
    /// Phase: `'X'` for complete events, `'M'` for metadata.
    pub ph: char,
    /// Start timestamp, microseconds.
    pub ts_us: f64,
    /// Duration, microseconds (`X` events only).
    pub dur_us: Option<f64>,
    /// Process id (lane group).
    pub pid: u64,
    /// Thread id (lane within the process).
    pub tid: u64,
    /// Free-form `args` payload shown in the viewer's detail pane.
    pub args: Json,
}

impl TraceEvent {
    /// A complete (`"ph":"X"`) event spanning `[ts_us, ts_us + dur_us]`.
    pub fn complete(name: &str, cat: &str, ts_us: f64, dur_us: f64, pid: u64, tid: u64) -> Self {
        TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            ph: 'X',
            ts_us,
            dur_us: Some(dur_us),
            pid,
            tid,
            args: Json::obj(),
        }
    }

    /// The `process_name` metadata event for `pid`.
    pub fn process_name(pid: u64, name: &str) -> Self {
        let mut args = Json::obj();
        args.set("name", Json::Str(name.to_string()));
        TraceEvent {
            name: "process_name".to_string(),
            cat: "__metadata".to_string(),
            ph: 'M',
            ts_us: 0.0,
            dur_us: None,
            pid,
            tid: 0,
            args,
        }
    }

    /// The `thread_name` metadata event for `(pid, tid)`.
    pub fn thread_name(pid: u64, tid: u64, name: &str) -> Self {
        let mut args = Json::obj();
        args.set("name", Json::Str(name.to_string()));
        TraceEvent {
            name: "thread_name".to_string(),
            cat: "__metadata".to_string(),
            ph: 'M',
            ts_us: 0.0,
            dur_us: None,
            pid,
            tid,
            args,
        }
    }

    /// Attach one `args` entry (builder style).
    pub fn arg(mut self, key: &str, value: Json) -> Self {
        self.args.set(key, value);
        self
    }

    /// The event as a trace-format JSON object.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("cat", Json::Str(self.cat.clone()))
            .set("ph", Json::Str(self.ph.to_string()))
            .set("ts", Json::Num(self.ts_us))
            .set("pid", Json::Num(self.pid as f64))
            .set("tid", Json::Num(self.tid as f64));
        if let Some(dur) = self.dur_us {
            j.set("dur", Json::Num(dur));
        }
        match &self.args {
            Json::Obj(map) if map.is_empty() => {}
            args => {
                j.set("args", args.clone());
            }
        }
        j
    }
}

/// Wrap events into the top-level trace object Perfetto loads:
/// `{"traceEvents":[...]}`.
pub fn trace_json(events: &[TraceEvent]) -> Json {
    let mut j = Json::obj();
    j.set(
        "traceEvents",
        Json::Arr(events.iter().map(TraceEvent::to_json).collect()),
    );
    j
}

/// Thread-safe span/event recorder over an injectable [`Clock`].
///
/// `serve` runs it on a [`super::MonotonicClock`]; tests inject a
/// [`super::LogicalClock`] and assert exact timestamps. Spans are
/// guard-based: [`SpanRecorder::span`] stamps the start, and dropping
/// the guard records one complete event.
pub struct SpanRecorder {
    clock: Arc<dyn Clock>,
    events: Mutex<Vec<TraceEvent>>,
}

impl std::fmt::Debug for SpanRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpanRecorder")
            .field("events", &self.events.lock().unwrap().len())
            .finish()
    }
}

impl SpanRecorder {
    /// A recorder stamping events from `clock`.
    pub fn new(clock: Arc<dyn Clock>) -> SpanRecorder {
        SpanRecorder {
            clock,
            events: Mutex::new(Vec::new()),
        }
    }

    /// Current clock reading, nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// Append an already-built event.
    pub fn record(&self, ev: TraceEvent) {
        self.events.lock().unwrap().push(ev);
    }

    /// Open a span on lane `(pid, tid)`; the returned guard records a
    /// complete event covering its lifetime when dropped.
    pub fn span(&self, name: &str, cat: &str, pid: u64, tid: u64) -> SpanGuard<'_> {
        SpanGuard {
            rec: self,
            name: name.to_string(),
            cat: cat.to_string(),
            pid,
            tid,
            start_ns: self.clock.now_ns(),
            args: Json::obj(),
        }
    }

    /// Events recorded so far.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take all recorded events, leaving the recorder empty.
    pub fn drain(&self) -> Vec<TraceEvent> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }
}

/// Live span handle from [`SpanRecorder::span`]; records its complete
/// event on drop.
pub struct SpanGuard<'a> {
    rec: &'a SpanRecorder,
    name: String,
    cat: String,
    pid: u64,
    tid: u64,
    start_ns: u64,
    args: Json,
}

impl SpanGuard<'_> {
    /// Attach one `args` entry to the event this span will record.
    pub fn arg(&mut self, key: &str, value: Json) {
        self.args.set(key, value);
    }

    /// The span's start timestamp, nanoseconds.
    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let end_ns = self.rec.now_ns();
        self.rec.record(TraceEvent {
            name: std::mem::take(&mut self.name),
            cat: std::mem::take(&mut self.cat),
            ph: 'X',
            ts_us: self.start_ns as f64 / 1000.0,
            dur_us: Some(end_ns.saturating_sub(self.start_ns) as f64 / 1000.0),
            pid: self.pid,
            tid: self.tid,
            args: std::mem::take(&mut self.args),
        });
    }
}

struct TraceFileInner {
    out: BufWriter<fs::File>,
    written: u64,
    finished: bool,
}

/// Streaming trace-file writer: emits a valid
/// `{"traceEvents":[...]}` document incrementally, so `serve --trace`
/// can append completed request spans without holding the whole trace
/// in memory.
pub struct TraceFileWriter {
    inner: Mutex<TraceFileInner>,
}

impl std::fmt::Debug for TraceFileWriter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceFileWriter").finish()
    }
}

impl TraceFileWriter {
    /// Create (truncate) `path` and write the document header.
    pub fn create(path: &Path) -> io::Result<TraceFileWriter> {
        let mut out = BufWriter::new(fs::File::create(path)?);
        out.write_all(b"{\"traceEvents\":[")?;
        Ok(TraceFileWriter {
            inner: Mutex::new(TraceFileInner {
                out,
                written: 0,
                finished: false,
            }),
        })
    }

    /// Append one event.
    pub fn write(&self, ev: &TraceEvent) -> io::Result<()> {
        self.write_all(std::slice::from_ref(ev))
    }

    /// Append a batch of events.
    pub fn write_all(&self, events: &[TraceEvent]) -> io::Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.finished {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "trace file already finished",
            ));
        }
        for ev in events {
            if inner.written > 0 {
                inner.out.write_all(b",\n")?;
            }
            let line = ev.to_json().dump();
            inner.out.write_all(line.as_bytes())?;
            inner.written += 1;
        }
        Ok(())
    }

    /// Close the JSON document and flush. Returns the event count.
    /// Idempotent; also invoked best-effort on drop.
    pub fn finish(&self) -> io::Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        if !inner.finished {
            inner.finished = true;
            inner.out.write_all(b"]}\n")?;
            inner.out.flush()?;
        }
        Ok(inner.written)
    }
}

impl Drop for TraceFileWriter {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::LogicalClock;

    #[test]
    fn event_json_shape() {
        let ev = TraceEvent::complete("op", "mxu", 1.5, 2.0, 1, 3).arg("index", Json::Num(7.0));
        let j = ev.to_json();
        assert_eq!(j.req_str("ph").unwrap(), "X");
        assert_eq!(j.req_f64("ts").unwrap(), 1.5);
        assert_eq!(j.req_f64("dur").unwrap(), 2.0);
        assert_eq!(j.req_f64("tid").unwrap(), 3.0);
        assert_eq!(j.get("args").unwrap().req_f64("index").unwrap(), 7.0);
        let m = TraceEvent::thread_name(1, 2, "vpu").to_json();
        assert_eq!(m.req_str("ph").unwrap(), "M");
        assert_eq!(m.get("args").unwrap().req_str("name").unwrap(), "vpu");
        assert!(m.get("dur").is_none());
    }

    #[test]
    fn logical_clock_spans_nest_deterministically() {
        let clock = Arc::new(LogicalClock::new());
        let rec = SpanRecorder::new(Arc::clone(&clock) as Arc<dyn Clock>);
        {
            let _outer = rec.span("request", "serve", 1, 9);
            clock.advance(1_000);
            {
                let mut inner = rec.span("estimate", "serve", 1, 9);
                inner.arg("hit", Json::Bool(true));
                clock.advance(5_000);
            }
            clock.advance(2_000);
        }
        let events = rec.drain();
        assert!(rec.is_empty());
        // Inner span drops first.
        assert_eq!(events.len(), 2);
        let (inner, outer) = (&events[0], &events[1]);
        assert_eq!(inner.name, "estimate");
        assert_eq!(inner.ts_us, 1.0);
        assert_eq!(inner.dur_us, Some(5.0));
        assert_eq!(outer.name, "request");
        assert_eq!(outer.ts_us, 0.0);
        assert_eq!(outer.dur_us, Some(8.0));
        // Time containment: the viewer nests inner under outer.
        assert!(outer.ts_us <= inner.ts_us);
        assert!(inner.ts_us + inner.dur_us.unwrap() <= outer.ts_us + outer.dur_us.unwrap());
    }

    #[test]
    fn trace_file_writer_produces_valid_json() {
        let dir = std::env::temp_dir().join("scalesim_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("writer.trace.json");
        let w = TraceFileWriter::create(&path).unwrap();
        w.write(&TraceEvent::complete("a", "c", 0.0, 1.0, 1, 1))
            .unwrap();
        w.write_all(&[
            TraceEvent::complete("b", "c", 1.0, 2.0, 1, 1),
            TraceEvent::process_name(1, "p"),
        ])
        .unwrap();
        assert_eq!(w.finish().unwrap(), 3);
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.req_arr("traceEvents").unwrap().len(), 3);
        assert!(w.write(&TraceEvent::process_name(1, "x")).is_err());
        std::fs::remove_file(&path).ok();
    }
}
