//! Exporters: Prometheus text exposition and the plaintext scrape
//! listener.
//!
//! [`render_prometheus`] turns a [`RegistrySnapshot`] into the
//! text-exposition format (version 0.0.4) Prometheus scrapes: `# HELP`
//! and `# TYPE` headers per family, `_total`-style counters, gauges,
//! and cumulative `_bucket{le=...}` / `_sum` / `_count` histogram
//! series. One deviation from the spec, inherent to the exact-count
//! log2 buckets: our bucket upper bounds are *exclusive* (`[2^k,
//! 2^(k+1))`), so an observation exactly equal to a boundary is counted
//! one bucket above where an inclusive-`le` reader would place it.
//!
//! [`MetricsScrape`] is a minimal HTTP/1.0 responder for
//! `serve --metrics ADDR:PORT`: every connection gets one rendered
//! snapshot, whatever the request bytes say, so `curl` and bare `nc`
//! both work.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use super::metrics::RegistrySnapshot;

/// Escape a label value per the exposition format.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a label set as `{k="v",...}`; empty string for no labels.
fn render_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

fn header(out: &mut String, family: &str, kind: &str, help: Option<&String>) {
    if let Some(h) = help {
        out.push_str(&format!("# HELP {family} {h}\n"));
    }
    out.push_str(&format!("# TYPE {family} {kind}\n"));
}

/// Render a registry snapshot in the Prometheus text exposition format.
///
/// Families appear in sorted order (counters, then gauges, then
/// histograms); `# HELP`/`# TYPE` are emitted once per family.
pub fn render_prometheus(snap: &RegistrySnapshot) -> String {
    let mut out = String::new();
    let mut last_family: Option<&str> = None;
    for (family, labels, value) in &snap.counters {
        if last_family != Some(family.as_str()) {
            header(&mut out, family, "counter", snap.help.get(family));
            last_family = Some(family);
        }
        out.push_str(&format!(
            "{family}{} {value}\n",
            render_labels(labels, None)
        ));
    }
    last_family = None;
    for (family, labels, value) in &snap.gauges {
        if last_family != Some(family.as_str()) {
            header(&mut out, family, "gauge", snap.help.get(family));
            last_family = Some(family);
        }
        out.push_str(&format!(
            "{family}{} {value}\n",
            render_labels(labels, None)
        ));
    }
    last_family = None;
    for (family, labels, hist) in &snap.histograms {
        if last_family != Some(family.as_str()) {
            header(&mut out, family, "histogram", snap.help.get(family));
            last_family = Some(family);
        }
        let mut cumulative = 0u64;
        for (i, &count) in hist.buckets.iter().enumerate() {
            cumulative += count;
            match hist.bucket_bound(i) {
                Some(bound) => out.push_str(&format!(
                    "{family}_bucket{} {cumulative}\n",
                    render_labels(labels, Some(("le", &bound.to_string())))
                )),
                None => out.push_str(&format!(
                    "{family}_bucket{} {cumulative}\n",
                    render_labels(labels, Some(("le", "+Inf")))
                )),
            }
        }
        out.push_str(&format!(
            "{family}_sum{} {}\n",
            render_labels(labels, None),
            hist.sum
        ));
        out.push_str(&format!(
            "{family}_count{} {}\n",
            render_labels(labels, None),
            hist.count
        ));
    }
    out
}

/// A minimal plaintext metrics endpoint (`serve --metrics ADDR:PORT`).
///
/// Binds a listener and answers every connection with one freshly
/// rendered exposition body over HTTP/1.0, then closes. The render
/// closure is injected so the observability layer stays agnostic of
/// what is being scraped. Stops (and joins its thread) on drop.
pub struct MetricsScrape {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl std::fmt::Debug for MetricsScrape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsScrape").field("addr", &self.addr).finish()
    }
}

impl MetricsScrape {
    /// Bind `addr` (e.g. `127.0.0.1:9100`) and serve `render()` output
    /// to every connection from a background thread.
    pub fn bind(
        addr: &str,
        render: Arc<dyn Fn() -> String + Send + Sync>,
    ) -> std::io::Result<MetricsScrape> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_thread = Arc::clone(&stop);
        let handle = thread::Builder::new()
            .name("metrics-scrape".to_string())
            .spawn(move || {
                while !stop_thread.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut stream, _)) => {
                            // Drain whatever request bytes arrived (best
                            // effort; a bare `nc` may send nothing).
                            let _ = stream.set_nonblocking(false);
                            let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
                            let mut buf = [0u8; 1024];
                            let _ = stream.read(&mut buf);
                            let body = render();
                            let resp = format!(
                                "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                                body.len(),
                                body
                            );
                            let _ = stream.write_all(resp.as_bytes());
                            let _ = stream.flush();
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(50));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(MetricsScrape {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener and join its thread (also happens on drop).
    pub fn stop(self) {}
}

impl Drop for MetricsScrape {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;
    use std::io::BufRead;

    fn sample_registry() -> Registry {
        let r = Registry::new();
        r.set_help("scalesim_requests_total", "requests served");
        r.counter("scalesim_requests_total", &[("type", "gemm")]).add(7);
        r.gauge("scalesim_pool_queue_depth", &[]).set(3);
        let h = r.histogram("scalesim_request_phase_ns", &[("phase", "estimate")], 4, 6);
        h.record(10); // underflow
        h.record(16);
        h.record(100); // overflow
        r
    }

    #[test]
    fn prometheus_rendering_shape() {
        let text = render_prometheus(&sample_registry().snapshot());
        assert!(text.contains("# HELP scalesim_requests_total requests served"));
        assert!(text.contains("# TYPE scalesim_requests_total counter"));
        assert!(text.contains("scalesim_requests_total{type=\"gemm\"} 7"));
        assert!(text.contains("# TYPE scalesim_pool_queue_depth gauge"));
        assert!(text.contains("scalesim_pool_queue_depth 3"));
        assert!(text.contains("# TYPE scalesim_request_phase_ns histogram"));
        // Cumulative buckets: le=16 holds the underflow, le=32 adds the
        // [16,32) observation, +Inf holds everything.
        assert!(text.contains("scalesim_request_phase_ns_bucket{phase=\"estimate\",le=\"16\"} 1"));
        assert!(text.contains("scalesim_request_phase_ns_bucket{phase=\"estimate\",le=\"32\"} 2"));
        assert!(
            text.contains("scalesim_request_phase_ns_bucket{phase=\"estimate\",le=\"+Inf\"} 3")
        );
        assert!(text.contains("scalesim_request_phase_ns_sum{phase=\"estimate\"} 126"));
        assert!(text.contains("scalesim_request_phase_ns_count{phase=\"estimate\"} 3"));
    }

    #[test]
    fn scrape_listener_answers_http() {
        let registry = Arc::new(sample_registry());
        let render: Arc<dyn Fn() -> String + Send + Sync> = {
            let registry = Arc::clone(&registry);
            Arc::new(move || render_prometheus(&registry.snapshot()))
        };
        let scrape = MetricsScrape::bind("127.0.0.1:0", render).unwrap();
        let addr = scrape.local_addr();
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut reader = std::io::BufReader::new(conn);
        let mut status = String::new();
        reader.read_line(&mut status).unwrap();
        assert!(status.starts_with("HTTP/1.0 200 OK"), "{status}");
        let mut body = String::new();
        reader.read_to_string(&mut body).unwrap();
        assert!(body.contains("scalesim_requests_total{type=\"gemm\"} 7"));
        scrape.stop();
    }
}
