//! Injectable clocks for the observability layer.
//!
//! Every timestamp the metrics and tracing code takes goes through the
//! [`Clock`] trait, so the serving stack can run on a real monotonic
//! clock while tests drive a [`LogicalClock`] by hand and assert exact
//! durations — no sleeps, no flaky tolerances.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A source of monotonic nanosecond timestamps.
///
/// Implementations must be cheap and thread-safe: `now_ns` is called on
/// the request hot path.
pub trait Clock: Send + Sync {
    /// Nanoseconds since the clock's origin. Monotonic non-decreasing.
    fn now_ns(&self) -> u64;
}

/// Wall-clock time via [`Instant`], anchored at construction.
///
/// The production clock: `serve` builds one per process, so every span
/// and histogram sample shares one origin and trace timestamps line up
/// across threads.
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    /// A clock whose origin is *now*.
    pub fn new() -> MonotonicClock {
        MonotonicClock {
            origin: Instant::now(),
        }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        // u64 nanoseconds cover ~584 years of process uptime.
        self.origin.elapsed().as_nanos() as u64
    }
}

/// A hand-advanced clock for deterministic tests.
///
/// Time moves only when the test calls [`LogicalClock::advance`] (or
/// [`LogicalClock::set`]), so span durations and histogram buckets are
/// exact values the test chose, not wall-clock noise.
#[derive(Debug, Default)]
pub struct LogicalClock {
    now: AtomicU64,
}

impl LogicalClock {
    /// A logical clock starting at zero.
    pub fn new() -> LogicalClock {
        LogicalClock::default()
    }

    /// Move time forward by `ns` nanoseconds.
    pub fn advance(&self, ns: u64) {
        self.now.fetch_add(ns, Ordering::Relaxed);
    }

    /// Jump the clock to an absolute time. Callers are responsible for
    /// keeping it monotonic.
    pub fn set(&self, ns: u64) {
        self.now.store(ns, Ordering::Relaxed);
    }
}

impl Clock for LogicalClock {
    fn now_ns(&self) -> u64 {
        self.now.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_does_not_go_backwards() {
        let c = MonotonicClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn logical_clock_is_hand_driven() {
        let c = LogicalClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(250);
        assert_eq!(c.now_ns(), 250);
        c.advance(750);
        assert_eq!(c.now_ns(), 1000);
        c.set(42);
        assert_eq!(c.now_ns(), 42);
    }
}
