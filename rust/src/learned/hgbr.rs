//! Histogram-based Gradient Boosting Regressor (HGBR).
//!
//! The paper's learned latency model: boosted regression trees over
//! binned features with least-squares loss, shrinkage and early stopping
//! on a held-out split. Matches the structure of sklearn's
//! `HistGradientBoostingRegressor`, implemented from scratch because the
//! offline registry carries no ML crates.
//!
//! Targets may optionally be fit in log space (`log_target = true`): for
//! latency prediction this balances relative error across the five
//! decades of tensor sizes the paper sweeps, which is what its median
//! *relative* error metric rewards.

use super::binning::BinnedMatrix;
use super::tree::{Tree, TreeParams};
use crate::util::json::{Json, JsonError};
use crate::util::prng::Prng;

/// Training hyper-parameters.
#[derive(Debug, Clone)]
pub struct HgbrParams {
    /// Boosting rounds (trees).
    pub max_iter: usize,
    /// Shrinkage per boosting round.
    pub learning_rate: f64,
    /// Histogram bins per feature.
    pub max_bins: usize,
    /// Per-tree growth limits.
    pub tree: TreeParams,
    /// Fraction of training data held out for early stopping (0 = off).
    pub validation_fraction: f64,
    /// Stop after this many iterations without validation improvement.
    pub early_stopping_rounds: usize,
    /// Fit log1p(target) instead of the raw target.
    pub log_target: bool,
    /// RNG seed for the validation split.
    pub seed: u64,
}

impl Default for HgbrParams {
    fn default() -> Self {
        HgbrParams {
            max_iter: 700,
            learning_rate: 0.1,
            max_bins: 256,
            tree: TreeParams::default(),
            validation_fraction: 0.1,
            early_stopping_rounds: 60,
            log_target: true,
            seed: 0x5ca1e,
        }
    }
}

/// A fitted model.
#[derive(Debug, Clone, PartialEq)]
pub struct Hgbr {
    /// Base prediction (mean of the target).
    pub base: f64,
    /// Shrinkage the trees were fit with.
    pub learning_rate: f64,
    /// Boosted trees, applied in order.
    pub trees: Vec<Tree>,
    /// Model was fit in log-latency space.
    pub log_target: bool,
    /// Names of the input features (documentation + sanity checks).
    pub feature_names: Vec<String>,
}

impl Hgbr {
    /// Train on sample-major rows and targets.
    pub fn fit(
        rows: &[Vec<f64>],
        targets: &[f64],
        feature_names: &[&str],
        params: &HgbrParams,
    ) -> Hgbr {
        assert_eq!(rows.len(), targets.len());
        assert!(!rows.is_empty());

        // Transform target.
        let y: Vec<f64> = if params.log_target {
            targets.iter().map(|&t| t.max(0.0).ln_1p()).collect()
        } else {
            targets.to_vec()
        };

        // Validation split.
        let n = rows.len();
        let n_val = if params.validation_fraction > 0.0 && n >= 20 {
            ((n as f64 * params.validation_fraction) as usize).max(1)
        } else {
            0
        };
        let mut prng = Prng::new(params.seed);
        let order = prng.sample_indices(n, n);
        let (val_idx, train_idx) = order.split_at(n_val);

        let train_rows: Vec<Vec<f64>> = train_idx.iter().map(|&i| rows[i].clone()).collect();
        let train_y: Vec<f64> = train_idx.iter().map(|&i| y[i]).collect();
        let val_rows: Vec<Vec<f64>> = val_idx.iter().map(|&i| rows[i].clone()).collect();
        let val_y: Vec<f64> = val_idx.iter().map(|&i| y[i]).collect();

        let data = BinnedMatrix::fit(&train_rows, params.max_bins);
        let base = train_y.iter().sum::<f64>() / train_y.len() as f64;

        let mut model = Hgbr {
            base,
            learning_rate: params.learning_rate,
            trees: Vec::new(),
            log_target: params.log_target,
            feature_names: feature_names.iter().map(|s| s.to_string()).collect(),
        };

        let mut pred: Vec<f64> = vec![base; train_y.len()];
        let mut val_pred: Vec<f64> = vec![base; val_y.len()];
        let mut best_val = f64::INFINITY;
        let mut best_len = 0usize;
        let mut rounds_no_improve = 0usize;

        for _iter in 0..params.max_iter {
            // LS gradients are just residuals.
            let residuals: Vec<f64> = train_y
                .iter()
                .zip(&pred)
                .map(|(t, p)| t - p)
                .collect();
            let tree = Tree::fit(&data, &residuals, &params.tree);
            if tree.num_leaves() < 2 {
                break; // nothing left to fit
            }
            // Update predictions.
            for (i, row) in train_rows.iter().enumerate() {
                pred[i] += params.learning_rate * tree.predict_row(row);
            }
            for (i, row) in val_rows.iter().enumerate() {
                val_pred[i] += params.learning_rate * tree.predict_row(row);
            }
            model.trees.push(tree);

            // Early stopping on validation MSE.
            if n_val > 0 {
                let mse: f64 = val_y
                    .iter()
                    .zip(&val_pred)
                    .map(|(t, p)| (t - p) * (t - p))
                    .sum::<f64>()
                    / n_val as f64;
                if mse < best_val - 1e-12 {
                    best_val = mse;
                    best_len = model.trees.len();
                    rounds_no_improve = 0;
                } else {
                    rounds_no_improve += 1;
                    if rounds_no_improve >= params.early_stopping_rounds {
                        break;
                    }
                }
            }
        }
        if n_val > 0 && best_len > 0 {
            model.trees.truncate(best_len);
        }
        model
    }

    /// Predict one raw feature row (in original target units).
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut acc = self.base;
        for tree in &self.trees {
            acc += self.learning_rate * tree.predict_row(row);
        }
        if self.log_target {
            acc.exp_m1().max(0.0)
        } else {
            acc
        }
    }

    /// Predict a batch.
    pub fn predict_batch(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }

    /// Number of boosted trees.
    pub fn num_trees(&self) -> usize {
        self.trees.len()
    }

    /// Serialize for the asset files.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("base", Json::Num(self.base))
            .set("learning_rate", Json::Num(self.learning_rate))
            .set("log_target", Json::Bool(self.log_target))
            .set(
                "feature_names",
                Json::Arr(
                    self.feature_names
                        .iter()
                        .map(|s| Json::Str(s.clone()))
                        .collect(),
                ),
            )
            .set(
                "trees",
                Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()),
            );
        o
    }

    /// Deserialize from the asset files.
    pub fn from_json(j: &Json) -> Result<Hgbr, JsonError> {
        let trees = j
            .req_arr("trees")?
            .iter()
            .map(Tree::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let feature_names = j
            .req_arr("feature_names")?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| JsonError::new("bad feature name"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Hgbr {
            base: j.req_f64("base")?,
            learning_rate: j.req_f64("learning_rate")?,
            trees,
            log_target: j
                .get("log_target")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            feature_names,
        })
    }

    /// Persist to a file.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Hgbr> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Hgbr::from_json(&j).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    /// y = 3x + noise-free quadratic wiggle over [0, 10].
    fn synth(n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let rows: Vec<Vec<f64>> = (0..n).map(|i| vec![10.0 * i as f64 / n as f64]).collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| 3.0 * r[0] + 0.5 * (r[0] - 5.0).powi(2))
            .collect();
        (rows, y)
    }

    #[test]
    fn fits_smooth_function() {
        let (rows, y) = synth(500);
        let model = Hgbr::fit(
            &rows,
            &y,
            &["x"],
            &HgbrParams {
                log_target: false,
                ..Default::default()
            },
        );
        let pred = model.predict_batch(&rows);
        let r2 = stats::r2(&y, &pred);
        assert!(r2 > 0.999, "r2 {r2}");
    }

    #[test]
    fn log_target_helps_wide_range() {
        // Latency-like target spanning 4 decades with multiplicative structure.
        let rows: Vec<Vec<f64>> = (1..=2000).map(|i| vec![(i * 97 % 2000) as f64]).collect();
        let y: Vec<f64> = rows.iter().map(|r| 0.01 * r[0].powf(1.5) + 1.0).collect();
        let model = Hgbr::fit(&rows, &y, &["x"], &HgbrParams::default());
        let pred = model.predict_batch(&rows);
        let mre = stats::median_rel_error(&y, &pred);
        assert!(mre < 3.0, "median rel err {mre}%");
    }

    #[test]
    fn extrapolates_to_unseen_inputs_without_nan() {
        let (rows, y) = synth(200);
        let model = Hgbr::fit(&rows, &y, &["x"], &HgbrParams::default());
        for x in [-5.0, 100.0, f64::MAX / 1e10] {
            let p = model.predict(&[x]);
            assert!(p.is_finite());
        }
    }

    #[test]
    fn early_stopping_limits_trees() {
        // Pure noise: validation loss cannot improve for long.
        let mut prng = Prng::new(3);
        let rows: Vec<Vec<f64>> = (0..300).map(|_| vec![prng.uniform()]).collect();
        let y: Vec<f64> = (0..300).map(|_| prng.uniform()).collect();
        let model = Hgbr::fit(
            &rows,
            &y,
            &["x"],
            &HgbrParams {
                max_iter: 700,
                log_target: false,
                ..Default::default()
            },
        );
        assert!(model.num_trees() < 400);
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (rows, y) = synth(300);
        let model = Hgbr::fit(&rows, &y, &["x"], &HgbrParams::default());
        let j = model.to_json();
        let model2 = Hgbr::from_json(&j).unwrap();
        for r in rows.iter().step_by(37) {
            assert_eq!(model.predict(r), model2.predict(r));
        }
        assert_eq!(model.feature_names, model2.feature_names);
    }

    #[test]
    fn save_load_file() {
        let (rows, y) = synth(100);
        let model = Hgbr::fit(&rows, &y, &["x"], &HgbrParams::default());
        let dir = std::env::temp_dir().join("scalesim_tpu_test_hgbr");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        model.save(&path).unwrap();
        let model2 = Hgbr::load(&path).unwrap();
        assert_eq!(model.predict(&[5.0]), model2.predict(&[5.0]));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn two_feature_interaction() {
        // y = x0 * x1 — needs depth to capture.
        let mut prng = Prng::new(7);
        let rows: Vec<Vec<f64>> = (0..2000)
            .map(|_| vec![prng.uniform_range(0.0, 10.0), prng.uniform_range(0.0, 10.0)])
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| r[0] * r[1]).collect();
        let model = Hgbr::fit(
            &rows,
            &y,
            &["a", "b"],
            &HgbrParams {
                log_target: false,
                ..Default::default()
            },
        );
        let pred = model.predict_batch(&rows);
        assert!(stats::r2(&y, &pred) > 0.98);
    }
}

/// Flattened, cache-friendly inference form of a trained [`Hgbr`].
///
/// All trees' nodes live in one struct-of-arrays block: no enum matching,
/// no per-tree pointer chasing. `feature == u32::MAX` marks a leaf whose
/// value sits in `threshold`. Produced by [`Hgbr::compile`]; ~4-5x faster
/// than walking the boxed trees (EXPERIMENTS.md §Perf L3).
#[derive(Debug, Clone)]
pub struct CompiledHgbr {
    base: f64,
    learning_rate: f64,
    log_target: bool,
    roots: Vec<u32>,
    feature: Vec<u32>,
    threshold: Vec<f64>,
    left: Vec<u32>,
    right: Vec<u32>,
}

const LEAF: u32 = u32::MAX;

impl Hgbr {
    /// Flatten the ensemble for fast inference.
    pub fn compile(&self) -> CompiledHgbr {
        let mut c = CompiledHgbr {
            base: self.base,
            learning_rate: self.learning_rate,
            log_target: self.log_target,
            roots: Vec::with_capacity(self.trees.len()),
            feature: Vec::new(),
            threshold: Vec::new(),
            left: Vec::new(),
            right: Vec::new(),
        };
        for tree in &self.trees {
            let offset = c.feature.len() as u32;
            c.roots.push(offset);
            for node in &tree.nodes {
                match node {
                    super::tree::Node::Leaf { value } => {
                        c.feature.push(LEAF);
                        c.threshold.push(*value);
                        c.left.push(0);
                        c.right.push(0);
                    }
                    super::tree::Node::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => {
                        c.feature.push(*feature as u32);
                        c.threshold.push(*threshold);
                        c.left.push(offset + *left as u32);
                        c.right.push(offset + *right as u32);
                    }
                }
            }
        }
        c
    }
}

impl CompiledHgbr {
    /// Predict one raw feature row (original target units).
    #[inline]
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut acc = self.base;
        for &root in &self.roots {
            let mut i = root as usize;
            loop {
                let f = self.feature[i];
                if f == LEAF {
                    acc += self.learning_rate * self.threshold[i];
                    break;
                }
                i = if row[f as usize] <= self.threshold[i] {
                    self.left[i] as usize
                } else {
                    self.right[i] as usize
                };
            }
        }
        if self.log_target {
            acc.exp_m1().max(0.0)
        } else {
            acc
        }
    }

    /// Predict a contiguous row-major batch: `rows` holds `n` feature
    /// rows of `stride` values each (`rows.len() == n * stride`), one
    /// prediction is appended to `out` per row. The batched estimator
    /// core evaluates all misses of one model through this so the hot
    /// loop runs over one flat array; each row goes through exactly
    /// [`CompiledHgbr::predict`], so batched predictions are
    /// bit-identical to scalar calls.
    pub fn predict_many(&self, rows: &[f64], stride: usize, out: &mut Vec<f64>) {
        assert!(stride > 0, "predict_many needs a positive row stride");
        assert_eq!(rows.len() % stride, 0, "rows must be a whole number of feature rows");
        out.reserve(rows.len() / stride);
        for row in rows.chunks_exact(stride) {
            out.push(self.predict(row));
        }
    }
}

#[cfg(test)]
mod compiled_tests {
    use super::*;

    #[test]
    fn predict_many_matches_scalar_predict() {
        let rows: Vec<Vec<f64>> = (0..300)
            .map(|i| vec![i as f64, (i * 53 % 71) as f64])
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| r[0] * 1.7 + r[1] + 2.0).collect();
        let compiled = Hgbr::fit(&rows, &y, &["a", "b"], &HgbrParams::default()).compile();
        let flat: Vec<f64> = rows.iter().flatten().copied().collect();
        let mut batch = Vec::new();
        compiled.predict_many(&flat, 2, &mut batch);
        assert_eq!(batch.len(), rows.len());
        for (row, got) in rows.iter().zip(&batch) {
            assert_eq!(compiled.predict(row).to_bits(), got.to_bits());
        }
    }

    #[test]
    fn compiled_matches_interpreted() {
        let rows: Vec<Vec<f64>> = (0..600)
            .map(|i| vec![i as f64, (i * 37 % 91) as f64])
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| r[0] * 0.3 + r[1] * 2.0 + 5.0).collect();
        let model = Hgbr::fit(&rows, &y, &["a", "b"], &HgbrParams::default());
        let compiled = model.compile();
        for r in rows.iter().step_by(13) {
            assert_eq!(model.predict(r), compiled.predict(r));
        }
        // Off-distribution inputs too.
        for r in [[1e9, -5.0], [-3.0, 1e6]] {
            assert_eq!(model.predict(&r), compiled.predict(&r));
        }
    }
}

impl Hgbr {
    /// Split-frequency feature importances, normalised to sum to 1.
    ///
    /// (Gain-based importances require keeping per-split gains; split
    /// counts are the standard lightweight proxy and suffice to verify
    /// the paper's claim that shape features carry signal beyond size.)
    pub fn feature_importances(&self) -> Vec<f64> {
        let nf = self.feature_names.len();
        let mut counts = vec![0f64; nf];
        for tree in &self.trees {
            for node in &tree.nodes {
                if let super::tree::Node::Split { feature, .. } = node {
                    if *feature < nf {
                        counts[*feature] += 1.0;
                    }
                }
            }
        }
        let total: f64 = counts.iter().sum();
        if total > 0.0 {
            for c in &mut counts {
                *c /= total;
            }
        }
        counts
    }

    /// (name, importance) pairs sorted descending.
    pub fn ranked_features(&self) -> Vec<(String, f64)> {
        let mut pairs: Vec<(String, f64)> = self
            .feature_names
            .iter()
            .cloned()
            .zip(self.feature_importances())
            .collect();
        pairs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        pairs
    }
}

#[cfg(test)]
mod importance_tests {
    use super::*;

    #[test]
    fn importances_sum_to_one_and_find_signal() {
        // Feature 0 drives the target; feature 1 is noise.
        let mut prng = Prng::new(11);
        let rows: Vec<Vec<f64>> = (0..800)
            .map(|_| vec![prng.uniform_range(0.0, 100.0), prng.uniform()])
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| r[0] * 2.0 + 1.0).collect();
        let m = Hgbr::fit(
            &rows,
            &y,
            &["signal", "noise"],
            &HgbrParams {
                log_target: false,
                max_iter: 60,
                ..Default::default()
            },
        );
        let imp = m.feature_importances();
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(imp[0] > 1.5 * imp[1], "signal {} vs noise {}", imp[0], imp[1]);
        let ranked = m.ranked_features();
        assert_eq!(ranked[0].0, "signal");
    }
}
