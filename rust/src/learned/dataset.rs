//! Measurement datasets for the learned latency models, with the paper's
//! train/validation protocol: train on a subset of tensor *sizes* and
//! evaluate on previously **unseen sizes** (§4.2, "Training and validation
//! protocol"), so the split tests generalisation rather than memorisation.

use std::collections::BTreeSet;

use super::features::featurize;
use crate::util::prng::Prng;

/// One measured sample: a tensor shape and its (median) latency.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Tensor shape of the measured kernel.
    pub dims: Vec<usize>,
    /// Median measured latency, µs.
    pub latency_us: f64,
}

impl Sample {
    /// Element count of the shape.
    pub fn num_elements(&self) -> u64 {
        self.dims.iter().map(|&d| d as u64).product::<u64>().max(1)
    }
}

/// A labelled dataset for one operator.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    /// Operator the samples measure (e.g. `add`).
    pub op_name: String,
    /// Measured (shape, latency) pairs.
    pub samples: Vec<Sample>,
}

impl Dataset {
    /// An empty dataset for one operator.
    pub fn new(op_name: &str) -> Dataset {
        Dataset {
            op_name: op_name.to_string(),
            samples: Vec::new(),
        }
    }

    /// Append one measurement.
    pub fn push(&mut self, dims: Vec<usize>, latency_us: f64) {
        self.samples.push(Sample { dims, latency_us });
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no sample was recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Feature matrix (sample-major) and target vector.
    pub fn features_targets(&self) -> (Vec<Vec<f64>>, Vec<f64>) {
        let rows = self.samples.iter().map(|s| featurize(&s.dims)).collect();
        let y = self.samples.iter().map(|s| s.latency_us).collect();
        (rows, y)
    }

    /// Split by *distinct total size*: `train_fraction` of the distinct
    /// element counts (randomly chosen) go to training; every sample whose
    /// size fell in the held-out set goes to test. Guarantees the test set
    /// contains only sizes never seen in training.
    pub fn split_by_unseen_sizes(&self, train_fraction: f64, seed: u64) -> (Dataset, Dataset) {
        let sizes: BTreeSet<u64> = self.samples.iter().map(|s| s.num_elements()).collect();
        let mut sizes: Vec<u64> = sizes.into_iter().collect();
        let mut prng = Prng::new(seed);
        prng.shuffle(&mut sizes);
        let n_train = ((sizes.len() as f64) * train_fraction).round() as usize;
        let train_sizes: BTreeSet<u64> = sizes.iter().take(n_train).copied().collect();

        let mut train = Dataset::new(&self.op_name);
        let mut test = Dataset::new(&self.op_name);
        for s in &self.samples {
            if train_sizes.contains(&s.num_elements()) {
                train.samples.push(s.clone());
            } else {
                test.samples.push(s.clone());
            }
        }
        (train, test)
    }

    /// CSV dump: `d0xd1x...,elements,latency_us`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("shape,elements,latency_us\n");
        for s in &self.samples {
            let shape = s
                .dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x");
            out.push_str(&format!(
                "{},{},{:.6}\n",
                if shape.is_empty() { "scalar".into() } else { shape },
                s.num_elements(),
                s.latency_us
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset_with_sizes() -> Dataset {
        let mut d = Dataset::new("add");
        // 10 distinct sizes, 2 shapes each.
        for i in 1..=10usize {
            let n = i * 64;
            d.push(vec![n], n as f64 * 0.01);
            d.push(vec![n / 2, 2], n as f64 * 0.011);
        }
        d
    }

    #[test]
    fn split_keeps_sizes_disjoint() {
        let d = dataset_with_sizes();
        let (train, test) = d.split_by_unseen_sizes(0.7, 42);
        assert_eq!(train.len() + test.len(), d.len());
        assert!(!train.is_empty());
        assert!(!test.is_empty());
        let train_sizes: BTreeSet<u64> = train.samples.iter().map(|s| s.num_elements()).collect();
        for s in &test.samples {
            assert!(!train_sizes.contains(&s.num_elements()));
        }
    }

    #[test]
    fn same_size_stays_together() {
        let d = dataset_with_sizes();
        let (train, _test) = d.split_by_unseen_sizes(0.5, 7);
        // Each size contributed 2 samples; they must travel together.
        let mut counts = std::collections::BTreeMap::new();
        for s in &train.samples {
            *counts.entry(s.num_elements()).or_insert(0usize) += 1;
        }
        for (_, c) in counts {
            assert_eq!(c, 2);
        }
    }

    #[test]
    fn features_align_with_targets() {
        let d = dataset_with_sizes();
        let (rows, y) = d.features_targets();
        assert_eq!(rows.len(), y.len());
        assert_eq!(rows[0][0], 64.0);
        assert!((y[0] - 0.64).abs() < 1e-12);
    }

    #[test]
    fn csv_format() {
        let mut d = Dataset::new("relu");
        d.push(vec![4, 8], 1.5);
        let csv = d.to_csv();
        assert!(csv.contains("4x8,32,1.5"));
    }
}
