//! Learned latency models for non-systolic (elementwise) operators.
//!
//! The paper's second contribution: histogram-based gradient-boosting
//! regression ([`hgbr`]) over tensor size/shape features ([`features`]),
//! trained on hardware measurements ([`dataset`]) with a split that holds
//! out entire tensor sizes. [`binning`] and [`tree`] are the from-scratch
//! HGBR internals; [`linear`] is the single-linear-model baseline the
//! paper argues trees beat.

pub mod binning;
pub mod dataset;
pub mod features;
pub mod hgbr;
pub mod linear;
pub mod tree;

pub use dataset::{Dataset, Sample};
pub use features::{feature_names, featurize};
pub use hgbr::{Hgbr, HgbrParams};
pub use linear::LinearLatencyModel;
