//! Linear-in-size latency baseline for the HGBR ablation.
//!
//! The paper motivates HGBR over "a single linear model" (§4.2, Model
//! choice): latency is *approximately* linear in element count but has
//! shape-dependent discontinuities a line cannot express. This model is
//! that straw-man, fitted by OLS on element count alone.

use crate::calibrate::linreg::LinearFit;

use super::dataset::Dataset;

/// Latency = α · elements + β.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearLatencyModel {
    /// OLS fit of latency vs element count.
    pub fit: LinearFit,
}

impl LinearLatencyModel {
    /// Fit the baseline on a dataset (None when degenerate).
    pub fn fit(dataset: &Dataset) -> Option<LinearLatencyModel> {
        let x: Vec<f64> = dataset
            .samples
            .iter()
            .map(|s| s.num_elements() as f64)
            .collect();
        let y: Vec<f64> = dataset.samples.iter().map(|s| s.latency_us).collect();
        LinearFit::fit(&x, &y).map(|fit| LinearLatencyModel { fit })
    }

    /// Predicted latency for a shape, µs.
    pub fn predict(&self, dims: &[usize]) -> f64 {
        let elems: u64 = dims.iter().map(|&d| d as u64).product::<u64>().max(1);
        self.fit.predict(elems as f64).max(0.0)
    }

    /// Predictions for every sample in the dataset.
    pub fn predict_dataset(&self, dataset: &Dataset) -> Vec<f64> {
        dataset.samples.iter().map(|s| self.predict(&s.dims)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_linear_data() {
        let mut d = Dataset::new("add");
        for i in 1..=20usize {
            d.push(vec![i * 100], 0.002 * (i * 100) as f64 + 3.0);
        }
        let m = LinearLatencyModel::fit(&d).unwrap();
        assert!((m.fit.alpha - 0.002).abs() < 1e-9);
        assert!((m.fit.beta - 3.0).abs() < 1e-9);
        assert!((m.predict(&[500]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn prediction_clamped_nonnegative() {
        let mut d = Dataset::new("add");
        d.push(vec![1000], 0.0);
        d.push(vec![2000], 10.0);
        let m = LinearLatencyModel::fit(&d).unwrap();
        assert!(m.predict(&[1]) >= 0.0);
    }

    #[test]
    fn empty_dataset_fails() {
        let d = Dataset::new("add");
        assert!(LinearLatencyModel::fit(&d).is_none());
    }
}
