//! Quantile feature binning for histogram-based gradient boosting.
//!
//! Continuous features are discretised into at most `max_bins` bins whose
//! edges are (approximate) quantiles of the training distribution — the
//! same trick LightGBM / sklearn's HistGradientBoosting use to make split
//! finding O(bins) instead of O(samples).

/// Per-feature bin mapper: sorted upper-bound thresholds. Value `x` maps
/// to the first bin whose threshold is >= x; values above all thresholds
/// map to the last bin.
#[derive(Debug, Clone, PartialEq)]
pub struct BinMapper {
    /// Upper (inclusive) boundary of each bin except the last, in
    /// increasing order. `thresholds.len() + 1` bins exist.
    pub thresholds: Vec<f64>,
}

impl BinMapper {
    /// Fit thresholds from one feature column.
    pub fn fit(values: &[f64], max_bins: usize) -> BinMapper {
        assert!(max_bins >= 2);
        let mut sorted: Vec<f64> = values.iter().copied().filter(|v| v.is_finite()).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted.dedup();
        if sorted.len() <= 1 {
            return BinMapper { thresholds: vec![] };
        }
        if sorted.len() <= max_bins {
            // One bin per distinct value: thresholds at midpoints.
            let thresholds = sorted
                .windows(2)
                .map(|w| 0.5 * (w[0] + w[1]))
                .collect();
            return BinMapper { thresholds };
        }
        // Quantile cuts.
        let mut thresholds = Vec::with_capacity(max_bins - 1);
        for b in 1..max_bins {
            let q = b as f64 / max_bins as f64;
            let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
            let t = sorted[idx];
            if thresholds.last().map(|&l| t > l).unwrap_or(true) {
                thresholds.push(t);
            }
        }
        BinMapper { thresholds }
    }

    /// Number of bins.
    pub fn num_bins(&self) -> usize {
        self.thresholds.len() + 1
    }

    /// Map a raw value to its bin index.
    pub fn bin(&self, x: f64) -> u16 {
        // partition_point: first index with threshold < x is false..
        let idx = self.thresholds.partition_point(|&t| t < x);
        idx as u16
    }

    /// The raw-value threshold separating bins `b` and `b+1` (split at
    /// "x <= threshold goes left").
    pub fn split_value(&self, b: u16) -> f64 {
        self.thresholds[b as usize]
    }
}

/// Binned training matrix: column-major bins plus the mappers.
#[derive(Debug, Clone)]
pub struct BinnedMatrix {
    /// One mapper per feature column.
    pub mappers: Vec<BinMapper>,
    /// `bins[f][i]` = bin of sample i's feature f.
    pub bins: Vec<Vec<u16>>,
    /// Rows the mappers were fit on.
    pub num_samples: usize,
}

impl BinnedMatrix {
    /// Fit mappers on `rows` (sample-major) and bin every sample.
    pub fn fit(rows: &[Vec<f64>], max_bins: usize) -> BinnedMatrix {
        assert!(!rows.is_empty());
        let num_features = rows[0].len();
        let num_samples = rows.len();
        let mut mappers = Vec::with_capacity(num_features);
        let mut bins = Vec::with_capacity(num_features);
        for f in 0..num_features {
            let col: Vec<f64> = rows.iter().map(|r| r[f]).collect();
            let mapper = BinMapper::fit(&col, max_bins);
            let col_bins: Vec<u16> = col.iter().map(|&v| mapper.bin(v)).collect();
            mappers.push(mapper);
            bins.push(col_bins);
        }
        BinnedMatrix {
            mappers,
            bins,
            num_samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn few_distinct_values_get_exact_bins() {
        let m = BinMapper::fit(&[1.0, 2.0, 2.0, 3.0], 256);
        assert_eq!(m.num_bins(), 3);
        assert_eq!(m.bin(1.0), 0);
        assert_eq!(m.bin(2.0), 1);
        assert_eq!(m.bin(3.0), 2);
        assert_eq!(m.bin(0.0), 0);
        assert_eq!(m.bin(99.0), 2);
    }

    #[test]
    fn constant_feature_single_bin() {
        let m = BinMapper::fit(&[5.0; 10], 256);
        assert_eq!(m.num_bins(), 1);
        assert_eq!(m.bin(5.0), 0);
        assert_eq!(m.bin(-1.0), 0);
    }

    #[test]
    fn quantile_bins_monotone() {
        let values: Vec<f64> = (0..10_000).map(|i| (i as f64).sqrt()).collect();
        let m = BinMapper::fit(&values, 64);
        assert!(m.num_bins() <= 64);
        assert!(m.num_bins() > 32);
        // Thresholds strictly increasing.
        for w in m.thresholds.windows(2) {
            assert!(w[0] < w[1]);
        }
        // Binning is monotone.
        let mut prev = 0u16;
        for v in [0.0, 1.0, 10.0, 50.0, 99.0] {
            let b = m.bin(v);
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn split_value_separates_bins() {
        let m = BinMapper::fit(&[1.0, 2.0, 3.0, 4.0], 256);
        let t = m.split_value(1); // between bins 1 and 2
        assert!(t > 2.0 && t < 3.0);
        assert!(m.bin(t) <= 1);
        assert!(m.bin(t + 0.51) >= 2);
    }

    #[test]
    fn binned_matrix_shape() {
        let rows = vec![
            vec![1.0, 10.0],
            vec![2.0, 20.0],
            vec![3.0, 30.0],
        ];
        let bm = BinnedMatrix::fit(&rows, 256);
        assert_eq!(bm.mappers.len(), 2);
        assert_eq!(bm.bins.len(), 2);
        assert_eq!(bm.bins[0].len(), 3);
        assert_eq!(bm.num_samples, 3);
        assert_eq!(bm.bins[1], vec![0, 1, 2]);
    }
}
