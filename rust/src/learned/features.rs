//! Feature extraction for the learned elementwise-latency models.
//!
//! Per the paper (§4.2), the features are the tensor *size* and *shape* —
//! both statically known at compile time. We expose the shape as
//! trailing-aligned dimensions (TPU layout effects attach to the minor
//! dims) plus derived size features; the tree model learns alignment and
//! vectorisation discontinuities from these raw values.

/// Number of trailing dims encoded explicitly.
pub const SHAPE_DIMS: usize = 4;

/// Feature names, parallel to [`featurize`]'s output.
pub fn feature_names() -> Vec<&'static str> {
    vec![
        "num_elements",
        "log2_elements",
        "rank",
        "dim_minor",      // last dim (lane dim on TPU)
        "dim_second",     // second-to-last (sublane dim)
        "dim_third",
        "dim_fourth",
        "min_dim",
        "max_dim",
        "minor_mod_128",  // distance from lane alignment
        "second_mod_8",   // distance from sublane alignment
        "padded_elements", // elements after (8,128) layout padding
        "log2_padded",
        "pad_waste",      // padded / raw ratio
    ]
}

/// Element count after TPU (8 sublanes × 128 lanes) layout padding — a
/// deterministic function of the shape (compile-time metadata), so it is
/// an admissible feature under the paper's "tensor size and shape" rule;
/// it encodes the layout knowledge that drives the shape-dependent
/// latency fluctuations the model must capture.
pub fn layout_padded_elements(dims: &[usize]) -> u64 {
    // XLA canonicalises away size-1 dims before choosing a layout.
    let dims: Vec<u64> = dims.iter().filter(|&&d| d > 1).map(|&d| d as u64).collect();
    match dims.len() {
        0 => 8 * 128,
        1 => dims[0].div_ceil(8 * 128) * (8 * 128),
        _ => {
            let minor = *dims.last().unwrap();
            let rows: u64 = dims[..dims.len() - 1].iter().product();
            rows.div_ceil(8) * 8 * minor.div_ceil(128) * 128
        }
    }
}

/// Build the feature row for a tensor shape.
pub fn featurize(dims: &[usize]) -> Vec<f64> {
    let elems: u64 = dims.iter().map(|&d| d as u64).product::<u64>().max(1);
    let rank = dims.len();

    // Trailing-aligned dims, padded with 1 for low ranks.
    let mut trail = [1usize; SHAPE_DIMS];
    for (i, &d) in dims.iter().rev().take(SHAPE_DIMS).enumerate() {
        trail[i] = d;
    }
    let min_dim = dims.iter().copied().min().unwrap_or(1).max(1);
    let max_dim = dims.iter().copied().max().unwrap_or(1).max(1);

    let padded = layout_padded_elements(dims);
    vec![
        elems as f64,
        (elems as f64).log2(),
        rank as f64,
        trail[0] as f64,
        trail[1] as f64,
        trail[2] as f64,
        trail[3] as f64,
        min_dim as f64,
        max_dim as f64,
        (trail[0] % 128) as f64,
        (trail[1] % 8) as f64,
        padded as f64,
        (padded as f64).log2(),
        padded as f64 / elems as f64,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_row_length() {
        assert_eq!(feature_names().len(), featurize(&[4, 5]).len());
    }

    #[test]
    fn scalar_shape() {
        let f = featurize(&[]);
        assert_eq!(f[0], 1.0); // elems
        assert_eq!(f[2], 0.0); // rank
        assert_eq!(f[3], 1.0); // minor dim padded
    }

    #[test]
    fn trailing_alignment() {
        let f = featurize(&[2, 3, 256]);
        assert_eq!(f[0], 1536.0);
        assert_eq!(f[2], 3.0);
        assert_eq!(f[3], 256.0); // minor
        assert_eq!(f[4], 3.0); // second-minor
        assert_eq!(f[5], 2.0);
        assert_eq!(f[6], 1.0);
        assert_eq!(f[9], 0.0); // 256 % 128
        assert_eq!(f[10], 3.0); // 3 % 8
    }

    #[test]
    fn same_size_different_shape_distinct() {
        let a = featurize(&[1024]);
        let b = featurize(&[32, 32]);
        assert_eq!(a[0], b[0]); // same size
        assert_ne!(a, b); // but distinguishable
    }

    #[test]
    fn min_max_dims() {
        let f = featurize(&[7, 128, 3]);
        assert_eq!(f[7], 3.0);
        assert_eq!(f[8], 128.0);
    }
}
