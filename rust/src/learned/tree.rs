//! Regression tree with histogram split finding (one boosting stage).
//!
//! Squared-error objective: with residuals r_i at a node holding n
//! samples, the optimal leaf value is Σr/(n+λ) and the split gain is
//!
//!   gain = Σ_L²/(n_L+λ) + Σ_R²/(n_R+λ) − Σ²/(n+λ)
//!
//! Split candidates are bin boundaries, so a node's best split is found in
//! O(features × bins) after one O(node samples) histogram pass. Growth is
//! best-first (leaf-wise, like LightGBM) to a `max_leaves` budget with a
//! `max_depth` guard.

use super::binning::BinnedMatrix;
use crate::util::json::{Json, JsonError};

/// One tree node. Internal nodes split on `feature <= threshold` (raw
/// value), leaves carry a prediction.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    /// An internal split node.
    Split {
        /// Feature column index the split tests.
        feature: usize,
        /// Raw-value threshold: x <= threshold → left.
        threshold: f64,
        /// Arena index of the left (x <= threshold) child.
        left: usize,
        /// Arena index of the right child.
        right: usize,
    },
    /// A terminal prediction node.
    Leaf {
        /// Predicted value (residual contribution).
        value: f64,
    },
}

/// A fitted regression tree (arena-allocated nodes, root = index 0).
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    /// Flat node storage; index 0 is the root.
    pub nodes: Vec<Node>,
}

/// Hyper-parameters for one tree fit.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    /// Leaf budget per tree.
    pub max_leaves: usize,
    /// Depth cap.
    pub max_depth: usize,
    /// Minimum samples a leaf may hold.
    pub min_samples_leaf: usize,
    /// L2 regularisation λ on leaf values.
    pub l2: f64,
    /// Minimum gain to accept a split.
    pub min_gain: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_leaves: 63,
            max_depth: 12,
            min_samples_leaf: 3,
            l2: 1.0,
            min_gain: 1e-12,
        }
    }
}

struct Candidate {
    node_idx: usize,
    depth: usize,
    gain: f64,
    feature: usize,
    bin: u16,
    left_samples: Vec<u32>,
    right_samples: Vec<u32>,
}

impl Tree {
    /// Fit a tree to `residuals` over the binned matrix.
    pub fn fit(data: &BinnedMatrix, residuals: &[f64], params: &TreeParams) -> Tree {
        assert_eq!(data.num_samples, residuals.len());
        let all: Vec<u32> = (0..data.num_samples as u32).collect();
        let mut tree = Tree { nodes: Vec::new() };

        // Root leaf.
        let root_value = leaf_value(&all, residuals, params.l2);
        tree.nodes.push(Node::Leaf { value: root_value });
        let mut leaves = 1usize;

        // Best-first frontier.
        let mut frontier: Vec<Candidate> = Vec::new();
        if let Some(c) = best_split(data, residuals, &all, 0, 0, params) {
            frontier.push(c);
        }

        while leaves < params.max_leaves {
            // Pop the highest-gain candidate.
            let Some(best_pos) = frontier
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.gain.partial_cmp(&b.1.gain).unwrap())
                .map(|(i, _)| i)
            else {
                break;
            };
            let cand = frontier.swap_remove(best_pos);

            // Materialise the split.
            let threshold = data.mappers[cand.feature].split_value(cand.bin);
            let left_idx = tree.nodes.len();
            let right_idx = left_idx + 1;
            let lv = leaf_value(&cand.left_samples, residuals, params.l2);
            let rv = leaf_value(&cand.right_samples, residuals, params.l2);
            tree.nodes.push(Node::Leaf { value: lv });
            tree.nodes.push(Node::Leaf { value: rv });
            tree.nodes[cand.node_idx] = Node::Split {
                feature: cand.feature,
                threshold,
                left: left_idx,
                right: right_idx,
            };
            leaves += 1;

            // Enqueue children.
            let depth = cand.depth + 1;
            if depth < params.max_depth {
                if let Some(c) =
                    best_split(data, residuals, &cand.left_samples, left_idx, depth, params)
                {
                    frontier.push(c);
                }
                if let Some(c) = best_split(
                    data,
                    residuals,
                    &cand.right_samples,
                    right_idx,
                    depth,
                    params,
                ) {
                    frontier.push(c);
                }
            }
        }
        tree
    }

    /// Predict a single raw-feature row.
    pub fn predict_row(&self, row: &[f64]) -> f64 {
        let mut idx = 0usize;
        loop {
            match &self.nodes[idx] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    idx = if row[*feature] <= *threshold {
                        *left
                    } else {
                        *right
                    };
                }
            }
        }
    }

    /// Number of leaf nodes.
    pub fn num_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }

    /// Serialize for the asset files.
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                let mut o = Json::obj();
                match n {
                    Node::Leaf { value } => {
                        o.set("value", Json::Num(*value));
                    }
                    Node::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => {
                        o.set("feature", Json::Num(*feature as f64))
                            .set("threshold", Json::Num(*threshold))
                            .set("left", Json::Num(*left as f64))
                            .set("right", Json::Num(*right as f64));
                    }
                }
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("nodes", Json::Arr(nodes));
        o
    }

    /// Deserialize from the asset files.
    pub fn from_json(j: &Json) -> Result<Tree, JsonError> {
        let arr = j.req_arr("nodes")?;
        let mut nodes = Vec::with_capacity(arr.len());
        for n in arr {
            if let Some(v) = n.get("value") {
                nodes.push(Node::Leaf {
                    value: v
                        .as_f64()
                        .ok_or_else(|| JsonError::new("bad leaf value"))?,
                });
            } else {
                nodes.push(Node::Split {
                    feature: n.req_f64("feature")? as usize,
                    threshold: n.req_f64("threshold")?,
                    left: n.req_f64("left")? as usize,
                    right: n.req_f64("right")? as usize,
                });
            }
        }
        if nodes.is_empty() {
            return Err(JsonError::new("empty tree"));
        }
        Ok(Tree { nodes })
    }
}

fn leaf_value(samples: &[u32], residuals: &[f64], l2: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let sum: f64 = samples.iter().map(|&i| residuals[i as usize]).sum();
    sum / (samples.len() as f64 + l2)
}

/// Find the best histogram split for a node, returning the realised
/// candidate (with child sample lists) or None if no admissible split.
fn best_split(
    data: &BinnedMatrix,
    residuals: &[f64],
    samples: &[u32],
    node_idx: usize,
    depth: usize,
    params: &TreeParams,
) -> Option<Candidate> {
    if samples.len() < 2 * params.min_samples_leaf {
        return None;
    }
    let total_sum: f64 = samples.iter().map(|&i| residuals[i as usize]).sum();
    let total_n = samples.len() as f64;
    let parent_score = total_sum * total_sum / (total_n + params.l2);

    let mut best: Option<(f64, usize, u16)> = None;

    for (f, mapper) in data.mappers.iter().enumerate() {
        let nbins = mapper.num_bins();
        if nbins < 2 {
            continue;
        }
        // Histogram pass.
        let mut hist_sum = vec![0.0f64; nbins];
        let mut hist_cnt = vec![0u32; nbins];
        let col = &data.bins[f];
        for &i in samples {
            let b = col[i as usize] as usize;
            hist_sum[b] += residuals[i as usize];
            hist_cnt[b] += 1;
        }
        // Scan split points left-to-right.
        let mut left_sum = 0.0f64;
        let mut left_cnt = 0u32;
        for b in 0..nbins - 1 {
            left_sum += hist_sum[b];
            left_cnt += hist_cnt[b];
            let right_cnt = samples.len() as u32 - left_cnt;
            if (left_cnt as usize) < params.min_samples_leaf
                || (right_cnt as usize) < params.min_samples_leaf
            {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let score = left_sum * left_sum / (left_cnt as f64 + params.l2)
                + right_sum * right_sum / (right_cnt as f64 + params.l2);
            let gain = score - parent_score;
            if gain > params.min_gain
                && best.map(|(g, _, _)| gain > g).unwrap_or(true)
            {
                best = Some((gain, f, b as u16));
            }
        }
    }

    let (gain, feature, bin) = best?;
    let col = &data.bins[feature];
    let mut left_samples = Vec::new();
    let mut right_samples = Vec::new();
    for &i in samples {
        if col[i as usize] <= bin {
            left_samples.push(i);
        } else {
            right_samples.push(i);
        }
    }
    Some(Candidate {
        node_idx,
        depth,
        gain,
        feature,
        bin,
        left_samples,
        right_samples,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fit_simple(rows: &[Vec<f64>], y: &[f64], params: TreeParams) -> Tree {
        let data = BinnedMatrix::fit(rows, 256);
        Tree::fit(&data, y, &params)
    }

    #[test]
    fn splits_a_step_function() {
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..100).map(|i| if i < 50 { -1.0 } else { 1.0 }).collect();
        let tree = fit_simple(
            &rows,
            &y,
            TreeParams {
                l2: 0.0,
                ..Default::default()
            },
        );
        assert!(tree.num_leaves() >= 2);
        assert!(tree.predict_row(&[10.0]) < -0.9);
        assert!(tree.predict_row(&[90.0]) > 0.9);
    }

    #[test]
    fn respects_max_leaves() {
        let rows: Vec<Vec<f64>> = (0..200).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..200).map(|i| (i as f64).sin()).collect();
        let tree = fit_simple(
            &rows,
            &y,
            TreeParams {
                max_leaves: 8,
                ..Default::default()
            },
        );
        assert!(tree.num_leaves() <= 8);
    }

    #[test]
    fn respects_min_samples_leaf() {
        let rows: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let tree = fit_simple(
            &rows,
            &y,
            TreeParams {
                min_samples_leaf: 10,
                l2: 0.0,
                ..Default::default()
            },
        );
        // With min 10 per leaf on 20 samples, at most one split.
        assert!(tree.num_leaves() <= 2);
    }

    #[test]
    fn constant_target_single_leaf() {
        let rows: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let y = vec![3.0; 50];
        let tree = fit_simple(&rows, &y, TreeParams::default());
        assert_eq!(tree.num_leaves(), 1);
        // λ=1 shrinks the mean slightly: 150/51.
        assert!((tree.predict_row(&[25.0]) - 150.0 / 51.0).abs() < 1e-9);
    }

    #[test]
    fn picks_informative_feature() {
        // Feature 1 is noise; feature 0 drives the target.
        let rows: Vec<Vec<f64>> = (0..100)
            .map(|i| vec![i as f64, (i * 7 % 13) as f64])
            .collect();
        let y: Vec<f64> = (0..100).map(|i| if i < 30 { 0.0 } else { 10.0 }).collect();
        let tree = fit_simple(
            &rows,
            &y,
            TreeParams {
                max_leaves: 2,
                l2: 0.0,
                ..Default::default()
            },
        );
        match &tree.nodes[0] {
            Node::Split { feature, threshold, .. } => {
                assert_eq!(*feature, 0);
                assert!(*threshold > 28.0 && *threshold < 31.0, "t={threshold}");
            }
            _ => panic!("expected root split"),
        }
    }

    #[test]
    fn json_roundtrip() {
        let rows: Vec<Vec<f64>> = (0..60).map(|i| vec![i as f64, (i % 5) as f64]).collect();
        let y: Vec<f64> = (0..60).map(|i| (i as f64) * 0.5).collect();
        let tree = fit_simple(&rows, &y, TreeParams::default());
        let j = tree.to_json();
        let tree2 = Tree::from_json(&j).unwrap();
        assert_eq!(tree, tree2);
        for i in [0.0, 17.0, 59.0] {
            assert_eq!(tree.predict_row(&[i, 0.0]), tree2.predict_row(&[i, 0.0]));
        }
    }
}
