//! Build-once / re-cost-many schedule templates.
//!
//! The scheduling pipeline used to re-derive everything from scratch on
//! every call: `PhaseModel::prefill_us` paid a full module clone
//! (`rewrite_seq`), a fresh estimator walk, a `DepGraph` build, a list
//! schedule and a DMA-timeline expansion for **every distinct prompt
//! length** — even though the DAG topology, SSA structure, engine
//! assignment rules and residency key-set are identical across sequence
//! rewrites. A [`ScheduleTemplate`] splits that pipeline:
//!
//! * **Capture** (once per module): the lowering event stream of the
//!   batched estimator ([`crate::coordinator::OpTable`]) — leaf order,
//!   inlined-`call` bracket structure — plus the memory timeline's
//!   [`TimelineShape`] (deduplicated operand/result id lists, SSA
//!   predecessor edges, the value-registration sequence) and the native
//!   per-leaf [`OpClass`] column.
//! * **Re-cost** (per prompt length / per cost vector): rewrite the
//!   per-leaf *shape column* ([`rewrite_op`] — no module clone), resolve
//!   all costs in **one** batched
//!   [`estimate_classes`](crate::coordinator::Estimator::estimate_classes)
//!   call, replay the event stream through the shared
//!   `assemble_events`, and replay the residency walk through the
//!   shared `price_shape`.
//!
//! **Exactness.** Re-cost results are *bit-identical* to the
//! from-scratch path, not approximately equal, because every stage is
//! the **same code**, not a replica:
//!
//! * `rewrite_seq(module, a, b)` is definitionally [`rewrite_op`]
//!   mapped over every op, so classifying the rewritten shape column
//!   equals classifying the rewritten module;
//! * cached cost values are pure functions of their shape key
//!   (independent of cache state), so one batched `estimate_classes`
//!   resolves the exact costs the from-scratch estimator walk would;
//! * row assembly runs the same event-replay fold (f64 addition is not
//!   associative — sharing the fold is what makes the totals exact);
//! * the residency walk replays the captured [`TimelineShape`] through
//!   the very walk `schedule_estimate_memory` runs (that function is
//!   itself capture + one replay).
//!
//! `tests/reuse_invariants.rs` pins this for every device preset ×
//! every `.mlir` fixture × a prompt-length sweep, plus interleaved
//! re-costs across devices and prompt lengths in shuffled call orders.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::batch::{assemble_events, LowerEvent};
use crate::coordinator::{CachedCost, Estimator, ModelEstimate};
use crate::frontend::classify::{classify, OpClass};
use crate::frontend::opinfo::{ModuleInfo, OpInfo};
use crate::inference::lower::{rewrite_op, rewrite_type};
use crate::memory::timeline::{call_engine, price_shape};
use crate::memory::{MemoryConfig, MemorySchedule, TimelineShape};

use super::engine::{Engine, EngineConfig};
use super::schedule::is_inlined_call;

/// One resolved per-leaf cost, as the batched estimator returns it
/// (source, optional cycle count, latency, note). [`ScheduleTemplate::recost`]
/// replays the schedule over a slice of these.
pub type OpCost = CachedCost;

/// The owned mirror of the batched estimator's lowering event stream
/// (the borrowed stream ties to a module's lifetime; the template must
/// outlive the module it was captured from).
enum OwnedEvent {
    /// Leaf column `.0` is estimated in place.
    Leaf(u32),
    /// A `call` op entering its callee.
    CallBegin {
        /// Index of the call op within its function.
        index: usize,
        /// Callee name (rendered as `call @callee`).
        callee: String,
    },
    /// Close the innermost open call.
    CallEnd,
}

/// A build-once schedule template: everything about one module's
/// scheduling pipeline that survives a change of per-op costs — node
/// order, edge lists, engine-assignment structure, DMA sub-node
/// structure and the residency touch sequence. Re-costing through it
/// skips re-parsing, re-classifying and re-allocating entirely; see the
/// [module docs](self) for the exactness argument.
pub struct ScheduleTemplate {
    config: EngineConfig,
    memory: MemoryConfig,
    /// The memory timeline's expand-once half.
    shape: TimelineShape,
    /// Leaf ops cloned in lowering order (entry ops at depth 0, inlined
    /// callee ops inside their call brackets).
    leaves: Vec<OpInfo>,
    /// SoA column: op index within its function, per leaf.
    indices: Vec<usize>,
    /// The lowering walk (leaves + call brackets) in program order.
    events: Vec<OwnedEvent>,
    /// Entry-op position → leaf column (`None` for folded `call` ops).
    entry_leaf: Vec<Option<usize>>,
    /// Per-leaf class column at the captured (native) extents.
    native_classes: Vec<OpClass>,
    /// Per-value byte column at the captured extents.
    native_bytes: Vec<u64>,
    /// Completed re-cost replays (the CI smoke asserts this is > 0 on
    /// the serving path).
    hits: AtomicU64,
}

fn lower_callee(
    module: &ModuleInfo,
    func_name: &str,
    depth: usize,
    events: &mut Vec<OwnedEvent>,
    leaves: &mut Vec<OpInfo>,
) {
    let Some(func) = module.funcs.iter().find(|f| f.name == func_name) else {
        return;
    };
    for op in &func.ops {
        // Follow calls into private sub-functions (depth-limited,
        // mirroring the batched lowering exactly).
        if (op.short_name() == "call" || op.op_name == "func.call") && depth < 4 {
            if let Some(callee) = &op.callee {
                events.push(OwnedEvent::CallBegin {
                    index: op.index,
                    callee: callee.clone(),
                });
                lower_callee(module, callee, depth + 1, events, leaves);
                events.push(OwnedEvent::CallEnd);
                continue;
            }
        }
        events.push(OwnedEvent::Leaf(leaves.len() as u32));
        leaves.push(op.clone());
    }
}

impl ScheduleTemplate {
    /// Capture a template from one lowering of `module` under an engine
    /// configuration and memory model. `None` when the module has no
    /// entry function.
    pub fn capture(
        module: &ModuleInfo,
        config: EngineConfig,
        memory: MemoryConfig,
    ) -> Option<ScheduleTemplate> {
        let shape = TimelineShape::capture(module)?;
        let entry = module.entry()?;
        let mut events: Vec<OwnedEvent> = Vec::new();
        let mut leaves: Vec<OpInfo> = Vec::new();
        let mut entry_leaf: Vec<Option<usize>> = Vec::with_capacity(entry.ops.len());
        for op in &entry.ops {
            if is_inlined_call(op) {
                let callee = op.callee.clone().expect("is_inlined_call implies a callee");
                events.push(OwnedEvent::CallBegin {
                    index: op.index,
                    callee: callee.clone(),
                });
                lower_callee(module, &callee, 1, &mut events, &mut leaves);
                events.push(OwnedEvent::CallEnd);
                entry_leaf.push(None);
            } else {
                entry_leaf.push(Some(leaves.len()));
                events.push(OwnedEvent::Leaf(leaves.len() as u32));
                leaves.push(op.clone());
            }
        }
        let indices: Vec<usize> = leaves.iter().map(|op| op.index).collect();
        let native_classes: Vec<OpClass> = leaves.iter().map(classify).collect();
        let native_bytes = shape.native_bytes();
        Some(ScheduleTemplate {
            config,
            memory,
            shape,
            leaves,
            indices,
            events,
            entry_leaf,
            native_classes,
            native_bytes,
            hits: AtomicU64::new(0),
        })
    }

    /// The engine configuration the template schedules onto.
    pub fn engine_config(&self) -> EngineConfig {
        self.config
    }

    /// The memory model (HBM rate + on-chip budget) replays price with.
    pub fn memory_config(&self) -> &MemoryConfig {
        &self.memory
    }

    /// Number of estimable leaf ops (inlined callee ops included).
    pub fn leaf_count(&self) -> usize {
        self.leaves.len()
    }

    /// Completed re-cost replays since capture.
    pub fn template_hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// The per-leaf class column at the captured extents. Feed it to
    /// [`Estimator::estimate_classes`] to resolve a cost slice for
    /// [`ScheduleTemplate::recost`] (externally scheduled re-costs, the
    /// batch estimator's sweep harness, tests).
    pub fn native_classes(&self) -> &[OpClass] {
        &self.native_classes
    }

    /// Replay the schedule over externally resolved per-leaf costs at
    /// the captured extents. `costs` aligns 1:1 with the leaf columns
    /// (one batched [`Estimator::estimate_classes`] call over
    /// the native class column produces exactly this slice).
    pub fn recost(&self, costs: &[OpCost]) -> MemorySchedule {
        self.replay(&self.native_classes, costs.to_vec(), &self.native_bytes)
    }

    /// Resolve costs through `est` (one batched `estimate_classes`
    /// probe) and replay at the captured extents. Bit-identical to
    /// `schedule_module_memory(est, module, config, memory)` — pinned
    /// by `tests/reuse_invariants.rs` for every preset × fixture.
    pub fn recost_native(&self, est: &Estimator) -> MemorySchedule {
        let costs = est.estimate_classes(&self.native_classes);
        self.replay(&self.native_classes, costs, &self.native_bytes)
    }

    /// The sequence-rewrite re-cost: every tensor dimension equal to
    /// `from` rewritten to `to` (the decode/prefill lowering of
    /// [`crate::inference::rewrite_seq`]), as a per-leaf shape-column
    /// rewrite + one batched estimate + one replay — no module clone.
    /// Bit-identical to `schedule_module_memory` over
    /// `rewrite_seq(module, from, to)`.
    pub fn recost_seq(&self, est: &Estimator, from: usize, to: usize) -> MemorySchedule {
        if from == to {
            // `rewrite_seq` is a no-op clone here; skip the column
            // rewrite (the rewritten classes would equal the native
            // ones bit for bit).
            return self.recost_native(est);
        }
        let classes: Vec<OpClass> = self
            .leaves
            .iter()
            .map(|op| classify(&rewrite_op(op, from, to)))
            .collect();
        let bytes: Vec<u64> = self
            .shape
            .values
            .iter()
            .map(|v| {
                v.ty.as_ref()
                    .map(|t| rewrite_type(t, from, to).size_bytes())
                    .unwrap_or(0)
            })
            .collect();
        let costs = est.estimate_classes(&classes);
        self.replay(&classes, costs, &bytes)
    }

    /// The assembled per-op estimate at the captured extents — the
    /// 1-chip regression surface: bit-identical to
    /// [`Estimator::estimate_module`], row by row
    /// (pinned in `tests/reuse_invariants.rs`).
    pub fn estimate_native(&self, est: &Estimator) -> ModelEstimate {
        let costs = est.estimate_classes(&self.native_classes);
        self.assemble(&self.native_classes, costs)
    }

    /// Replay the lowering event stream over per-leaf costs through the
    /// shared `assemble_events` fold.
    fn assemble(&self, classes: &[OpClass], costs: Vec<CachedCost>) -> ModelEstimate {
        debug_assert_eq!(classes.len(), self.leaves.len());
        debug_assert_eq!(costs.len(), self.leaves.len());
        let names: Vec<&str> = self.leaves.iter().map(|op| op.op_name.as_str()).collect();
        let events: Vec<LowerEvent<'_>> = self
            .events
            .iter()
            .map(|e| match e {
                OwnedEvent::Leaf(l) => LowerEvent::Leaf(*l),
                OwnedEvent::CallBegin { index, callee } => LowerEvent::CallBegin {
                    index: *index,
                    callee: callee.as_str(),
                },
                OwnedEvent::CallEnd => LowerEvent::CallEnd,
            })
            .collect();
        assemble_events(
            &self.shape.module_name,
            &events,
            &self.indices,
            &names,
            classes,
            costs,
        )
    }

    /// Assemble rows, derive per-entry-op engines from the class
    /// column, and replay the residency walk.
    fn replay(&self, classes: &[OpClass], costs: Vec<CachedCost>, bytes: &[u64]) -> MemorySchedule {
        let report = self.assemble(classes, costs);
        let engines: Vec<Option<Engine>> = self
            .shape
            .ops
            .iter()
            .zip(&self.entry_leaf)
            .map(|(sop, leaf)| {
                if sop.inlined_call {
                    call_engine(self.config)
                } else {
                    let l = leaf.expect("non-call entry ops map to a leaf column");
                    self.config.engine_of(&classes[l])
                }
            })
            .collect();
        let out = price_shape(
            &self.shape,
            &report.ops,
            &engines,
            self.config,
            &self.memory,
            bytes,
        );
        self.hits.fetch_add(1, Ordering::Relaxed);
        out
    }
}
