//! The engine model: which hardware unit executes each op class.
//!
//! A TPU chip is modeled as a small set of concurrently running engines.
//! The scheduler places each op on the engine its [`OpClass`] routes to;
//! ops on different engines overlap as long as their data dependences
//! allow. Three configurations are provided:
//!
//! * [`EngineConfig::Serialized`] — one lane, every op in program order.
//!   This is the degenerate baseline: its makespan is *bit-identical* to
//!   the unfused [`estimate_module`](crate::coordinator::Estimator::estimate_module)
//!   sum (tested), which anchors the scheduler against the existing
//!   estimator.
//! * [`EngineConfig::ComputeIci`] — one compute lane plus the ICI lane:
//!   the per-chip timeline the distributed slice estimator uses (only
//!   collectives overlap with compute).
//! * [`EngineConfig::Tpu`] — the full engine set: MXU (systolic GEMM /
//!   conv), VPU (elementwise, reductions), DMA (bandwidth-class data
//!   movement), ICI (collectives). Compile-time-free ops occupy no
//!   engine at all.

use crate::frontend::classify::OpClass;

/// One hardware execution engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Systolic matrix unit: GEMMs and im2col-lowered convolutions.
    Mxu,
    /// Vector unit: elementwise arithmetic and reductions.
    Vpu,
    /// HBM DMA: relayouts and other bandwidth-bound byte movement.
    Dma,
    /// Inter-chip interconnect: collectives.
    Ici,
    /// The single lane of the serialized baseline configuration.
    Unified,
}

impl Engine {
    /// Every engine, in lane order.
    pub const ALL: [Engine; 5] = [
        Engine::Mxu,
        Engine::Vpu,
        Engine::Dma,
        Engine::Ici,
        Engine::Unified,
    ];

    /// Lowercase engine name.
    pub fn name(&self) -> &'static str {
        match self {
            Engine::Mxu => "mxu",
            Engine::Vpu => "vpu",
            Engine::Dma => "dma",
            Engine::Ici => "ici",
            Engine::Unified => "unified",
        }
    }

    /// Dense lane index for the scheduler's availability array.
    pub(crate) fn lane(self) -> usize {
        match self {
            Engine::Mxu => 0,
            Engine::Vpu => 1,
            Engine::Dma => 2,
            Engine::Ici => 3,
            Engine::Unified => 4,
        }
    }
}

impl std::fmt::Display for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// How op classes map onto engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineConfig {
    /// Every op serializes on one lane in program order. Reproduces the
    /// plain unfused module sum bit for bit.
    Serialized,
    /// One compute lane + the ICI lane (the distributed slice model).
    ComputeIci,
    /// The full TPU engine set: MXU / VPU / DMA / ICI.
    Tpu,
}

impl EngineConfig {
    /// The engine set a device schedules onto. Devices with at least one
    /// dedicated DMA engine get the full [`EngineConfig::Tpu`] set; a
    /// device with no DMA engine serializes explicit data movement onto
    /// its compute lane, which is exactly the [`EngineConfig::ComputeIci`]
    /// routing (one compute lane + the ICI lane).
    pub fn for_device(spec: &crate::device::DeviceSpec) -> EngineConfig {
        if spec.dma_engines == 0 {
            EngineConfig::ComputeIci
        } else {
            EngineConfig::Tpu
        }
    }

    /// Lowercase configuration name.
    pub fn name(&self) -> &'static str {
        match self {
            EngineConfig::Serialized => "serialized",
            EngineConfig::ComputeIci => "compute+ici",
            EngineConfig::Tpu => "tpu",
        }
    }

    /// The engines this configuration schedules onto, in display order.
    pub fn engines(&self) -> &'static [Engine] {
        match self {
            EngineConfig::Serialized => &[Engine::Unified],
            EngineConfig::ComputeIci => &[Engine::Mxu, Engine::Ici],
            EngineConfig::Tpu => &[Engine::Mxu, Engine::Vpu, Engine::Dma, Engine::Ici],
        }
    }

    /// Route a classified op to its engine. `None` means the op is
    /// zero-width: it occupies no engine and finishes the instant its
    /// operands are ready.
    pub fn engine_of(&self, class: &OpClass) -> Option<Engine> {
        match self {
            EngineConfig::Serialized => Some(Engine::Unified),
            EngineConfig::ComputeIci => match class {
                OpClass::Collective { .. } => Some(Engine::Ici),
                _ => Some(Engine::Mxu),
            },
            EngineConfig::Tpu => match class {
                OpClass::SystolicGemm { .. } | OpClass::SystolicConv { .. } => {
                    Some(Engine::Mxu)
                }
                OpClass::Elementwise { .. } | OpClass::Reduction { .. } => Some(Engine::Vpu),
                OpClass::DataMovement { .. } | OpClass::Unmodeled { .. } => Some(Engine::Dma),
                OpClass::Collective { .. } => Some(Engine::Ici),
                OpClass::Free => None,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::classify::{CollectiveKind, EwKind};
    use crate::frontend::types::{DType, TensorType};
    use crate::scalesim::topology::GemmShape;

    fn t(dims: &[usize]) -> TensorType {
        TensorType::new(dims.to_vec(), DType::Bf16)
    }

    #[test]
    fn tpu_routing_table() {
        let config = EngineConfig::Tpu;
        let gemm = OpClass::SystolicGemm {
            gemm: GemmShape::new(8, 8, 8),
            count: 1,
        };
        assert_eq!(config.engine_of(&gemm), Some(Engine::Mxu));
        let ew = OpClass::Elementwise {
            kind: EwKind::Add,
            out: t(&[8, 8]),
        };
        assert_eq!(config.engine_of(&ew), Some(Engine::Vpu));
        let red = OpClass::Reduction {
            input: t(&[8, 8]),
            out: t(&[8]),
        };
        assert_eq!(config.engine_of(&red), Some(Engine::Vpu));
        let mv = OpClass::DataMovement {
            bytes: 64,
            out: t(&[8, 8]),
        };
        assert_eq!(config.engine_of(&mv), Some(Engine::Dma));
        let coll = OpClass::Collective {
            kind: CollectiveKind::AllReduce,
            bytes_in: 64,
            out: t(&[8, 8]),
        };
        assert_eq!(config.engine_of(&coll), Some(Engine::Ici));
        assert_eq!(config.engine_of(&OpClass::Free), None);
    }

    #[test]
    fn serialized_routes_everything_to_one_lane() {
        let config = EngineConfig::Serialized;
        assert_eq!(config.engine_of(&OpClass::Free), Some(Engine::Unified));
        assert_eq!(config.engines(), &[Engine::Unified]);
    }

    #[test]
    fn compute_ici_splits_only_collectives() {
        let config = EngineConfig::ComputeIci;
        let coll = OpClass::Collective {
            kind: CollectiveKind::AllGather,
            bytes_in: 64,
            out: t(&[8, 8]),
        };
        assert_eq!(config.engine_of(&coll), Some(Engine::Ici));
        assert_eq!(config.engine_of(&OpClass::Free), Some(Engine::Mxu));
    }

    #[test]
    fn engine_set_derives_from_the_device() {
        use crate::device::DeviceSpec;
        let v4 = DeviceSpec::tpu_v4();
        assert_eq!(EngineConfig::for_device(&v4), EngineConfig::Tpu);
        let mut no_dma = v4;
        no_dma.dma_engines = 0;
        assert_eq!(EngineConfig::for_device(&no_dma), EngineConfig::ComputeIci);
    }

    #[test]
    fn lanes_are_dense_and_distinct() {
        let mut seen = [false; Engine::ALL.len()];
        for e in Engine::ALL {
            assert!(!seen[e.lane()], "lane collision for {e}");
            seen[e.lane()] = true;
        }
    }
}
