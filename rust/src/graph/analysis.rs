//! Schedule analysis: critical path, per-op slack, per-engine busy/idle
//! breakdown, and the serialized timeline.
//!
//! Slack is *dependence slack against the realized schedule*: how far an
//! op's finish could slip — holding every other placement fixed and
//! honoring only data dependences — before the module's makespan moves.
//! Ops with zero slack form the schedule's critical chain(s); the
//! separate `critical_path_us` is the resource-unconstrained longest
//! dependence chain (a lower bound on any schedule's makespan).

use crate::obs::TraceEvent;
use crate::util::json::Json;

use super::engine::{Engine, EngineConfig};
use super::schedule::{place, ready_time, Placement, SchedNode};

/// One op's placement in the final schedule.
#[derive(Debug, Clone)]
pub struct ScheduledOp {
    /// Index of the source op within its function.
    pub index: usize,
    /// Display name of the op.
    pub op_name: String,
    /// `None` for zero-width ops (no engine occupied).
    pub engine: Option<Engine>,
    /// Cost carried from the estimate row, µs.
    pub latency_us: f64,
    /// Placed start time, µs.
    pub start_us: f64,
    /// Placed finish time, µs.
    pub end_us: f64,
    /// Dependence slack against the realized makespan (>= 0).
    pub slack_us: f64,
    /// Cost-model tag (an `EstimateSource` tag or `"call"`).
    pub source: &'static str,
    /// Shape/context note carried from the estimate.
    pub note: String,
}

impl ScheduledOp {
    /// On the critical chain: the makespan moves if this op slips.
    pub fn critical(&self) -> bool {
        self.slack_us <= 1e-9
    }

    fn engine_name(&self) -> &'static str {
        self.engine.map(|e| e.name()).unwrap_or("-")
    }

    /// The op row's schedule fields as one JSON object — the single
    /// source of truth for the per-op schema (the CLI `--json` path
    /// layers estimator-only fields like `cycles` on top of this).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("index", Json::Num(self.index as f64))
            .set("op", Json::Str(self.op_name.clone()))
            .set(
                "engine",
                match self.engine {
                    Some(e) => Json::Str(e.name().to_string()),
                    None => Json::Null,
                },
            )
            .set("latency_us", Json::Num(self.latency_us))
            .set("start_us", Json::Num(self.start_us))
            .set("end_us", Json::Num(self.end_us))
            .set("slack_us", Json::Num(self.slack_us))
            .set("critical", Json::Bool(self.critical()))
            .set("source", Json::Str(self.source.to_string()))
            .set("note", Json::Str(self.note.clone()));
        o
    }
}

/// Roofline verdict for one op: which resource its time is dominated by.
///
/// An op is *bandwidth-bound* when the HBM traffic behind it (DMA-in +
/// DMA-out, as modeled by [`crate::memory`]) takes longer than its
/// compute; ops with neither compute nor traffic are *free*.
pub fn op_bound(compute_us: f64, dma_us: f64) -> &'static str {
    if compute_us <= 0.0 && dma_us <= 0.0 {
        "free"
    } else if dma_us > compute_us {
        "bandwidth"
    } else {
        "compute"
    }
}

/// Aggregate roofline summary over a memory-aware schedule: how many ops
/// land on each side of the compute-vs-bandwidth roofline, and the busy
/// time each side contributes. Built by
/// [`schedule_estimate_memory`](crate::memory::schedule_estimate_memory);
/// reported by the CLI and the `serve` module responses.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RooflineSummary {
    /// Ops whose compute time dominates their HBM traffic.
    pub compute_bound: usize,
    /// Ops whose HBM traffic dominates their compute time.
    pub bandwidth_bound: usize,
    /// Ops with neither compute nor traffic.
    pub free_ops: usize,
    /// Total compute time across all ops, µs.
    pub compute_us: f64,
    /// Total DMA (HBM traffic) time across all ops, µs.
    pub dma_us: f64,
}

impl RooflineSummary {
    /// Fold one op's compute/DMA split into the summary.
    pub fn record(&mut self, compute_us: f64, dma_us: f64) {
        match op_bound(compute_us, dma_us) {
            "bandwidth" => self.bandwidth_bound += 1,
            "compute" => self.compute_bound += 1,
            _ => self.free_ops += 1,
        }
        self.compute_us += compute_us;
        self.dma_us += dma_us;
    }

    /// Whole-module verdict: which side dominates the total busy time.
    pub fn verdict(&self) -> &'static str {
        if self.dma_us > self.compute_us {
            "bandwidth-bound"
        } else {
            "compute-bound"
        }
    }

    /// One human-readable summary line.
    pub fn render(&self) -> String {
        format!(
            "roofline: {} compute-bound / {} bandwidth-bound / {} free ops; compute {:.2} us vs dma {:.2} us => {}",
            self.compute_bound,
            self.bandwidth_bound,
            self.free_ops,
            self.compute_us,
            self.dma_us,
            self.verdict()
        )
    }

    /// The summary as a JSON object (the `roofline` payload of `--json`
    /// and `serve` module responses).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("compute_bound", Json::Num(self.compute_bound as f64))
            .set("bandwidth_bound", Json::Num(self.bandwidth_bound as f64))
            .set("free", Json::Num(self.free_ops as f64))
            .set("compute_us", Json::Num(self.compute_us))
            .set("dma_us", Json::Num(self.dma_us))
            .set("verdict", Json::Str(self.verdict().to_string()));
        j
    }
}

/// Busy/idle accounting for one engine over the whole schedule.
#[derive(Debug, Clone, Copy)]
pub struct EngineUsage {
    /// The engine accounted.
    pub engine: Engine,
    /// Summed cost of ops placed here, µs.
    pub busy_us: f64,
    /// Makespan minus busy time, µs.
    pub idle_us: f64,
    /// Ops placed on this engine.
    pub ops: usize,
}

impl EngineUsage {
    /// Fraction of the makespan this engine was busy, in [0, 1].
    pub fn utilization(&self) -> f64 {
        let span = self.busy_us + self.idle_us;
        if span > 0.0 {
            self.busy_us / span
        } else {
            0.0
        }
    }
}

/// A whole-module schedule plus its analyses.
#[derive(Debug, Clone)]
pub struct ModuleSchedule {
    /// Module the schedule covers.
    pub module_name: String,
    /// Engine configuration scheduled onto.
    pub config: EngineConfig,
    /// When the last engine goes idle.
    pub makespan_us: f64,
    /// Longest dependence chain ignoring engine contention: no schedule
    /// on any engine set can beat this.
    pub critical_path_us: f64,
    /// Per-node rows in node order.
    pub ops: Vec<ScheduledOp>,
    /// One entry per engine in `config.engines()`, in display order.
    pub engines: Vec<EngineUsage>,
}

/// Longest dependence chain through costed nodes, ignoring engines.
///
/// Computed with the same fold order as [`place`]'s ready times, so
/// `critical_path(nodes) <= makespan` holds exactly in floating point.
pub fn critical_path(nodes: &[SchedNode]) -> f64 {
    let mut cp: Vec<Placement> = Vec::with_capacity(nodes.len());
    for node in nodes {
        let ready = ready_time(&node.preds, &cp);
        cp.push(Placement {
            start_us: ready,
            end_us: ready + node.cost_us,
        });
    }
    cp.iter().fold(0.0f64, |acc, p| acc.max(p.end_us))
}

/// Run the scheduler over prepared nodes and attach every analysis.
pub fn finish_schedule(
    module_name: String,
    config: EngineConfig,
    nodes: Vec<SchedNode>,
) -> ModuleSchedule {
    let placements = place(&nodes);
    let makespan_us = placements.iter().fold(0.0f64, |acc, p| acc.max(p.end_us));
    let critical_path_us = critical_path(&nodes);

    // Latest dependence-feasible finish times, walked sinks-first.
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for (i, node) in nodes.iter().enumerate() {
        for &p in &node.preds {
            succs[p].push(i);
        }
    }
    let mut late = vec![makespan_us; nodes.len()];
    for i in (0..nodes.len()).rev() {
        for &s in &succs[i] {
            late[i] = late[i].min(late[s] - nodes[s].cost_us);
        }
    }

    let mut engines: Vec<EngineUsage> = config
        .engines()
        .iter()
        .map(|&engine| EngineUsage {
            engine,
            busy_us: 0.0,
            idle_us: 0.0,
            ops: 0,
        })
        .collect();
    for node in &nodes {
        if let Some(e) = node.engine {
            if let Some(u) = engines.iter_mut().find(|u| u.engine == e) {
                // Sum costs (not end-start spans): the same accumulation
                // order as the estimator's per-class totals, so e.g. MXU
                // busy time is bit-identical to `systolic_us`.
                u.busy_us += node.cost_us;
                u.ops += 1;
            }
        }
    }
    for u in &mut engines {
        u.idle_us = (makespan_us - u.busy_us).max(0.0);
    }

    let ops: Vec<ScheduledOp> = nodes
        .into_iter()
        .zip(&placements)
        .zip(&late)
        .map(|((node, p), &l)| ScheduledOp {
            index: node.index,
            op_name: node.op_name,
            engine: node.engine,
            latency_us: node.cost_us,
            start_us: p.start_us,
            end_us: p.end_us,
            slack_us: (l - p.end_us).max(0.0),
            source: node.source,
            note: node.note,
        })
        .collect();

    ModuleSchedule {
        module_name,
        config,
        makespan_us,
        critical_path_us,
        ops,
        engines,
    }
}

impl ModuleSchedule {
    /// Usage row for one engine, if the config schedules onto it.
    pub fn usage(&self, engine: Engine) -> Option<&EngineUsage> {
        self.engines.iter().find(|u| u.engine == engine)
    }

    /// Busy time summed over every engine (the schedule's work content).
    pub fn busy_us(&self) -> f64 {
        self.engines.iter().map(|u| u.busy_us).sum()
    }

    /// Human-readable timeline, one line per op sorted by start time.
    /// Critical-chain ops are starred.
    pub fn render_timeline(&self) -> String {
        let mut order: Vec<usize> = (0..self.ops.len()).collect();
        order.sort_by(|&a, &b| {
            self.ops[a]
                .start_us
                .partial_cmp(&self.ops[b].start_us)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut out = format!(
            "timeline @{} ({} engines): makespan {:.3} us, critical path {:.3} us\n",
            self.module_name,
            self.config.name(),
            self.makespan_us,
            self.critical_path_us
        );
        for &i in &order {
            let op = &self.ops[i];
            out.push_str(&format!(
                "  [{:>10.3} ..{:>10.3}] {:<7} #{:<3} {}{}{}\n",
                op.start_us,
                op.end_us,
                op.engine_name(),
                op.index,
                op.op_name,
                if op.critical() { " *" } else { "" },
                if op.note.is_empty() {
                    String::new()
                } else {
                    format!("  ({})", op.note)
                },
            ));
        }
        for u in &self.engines {
            out.push_str(&format!(
                "  engine {:<7} busy {:.3} us / idle {:.3} us ({:.1}% utilized, {} ops)\n",
                u.engine.name(),
                u.busy_us,
                u.idle_us,
                u.utilization() * 100.0,
                u.ops
            ));
        }
        out
    }

    /// Per-engine usage as a JSON object keyed by engine name.
    pub fn engines_to_json(&self) -> Json {
        let mut obj = Json::obj();
        for u in &self.engines {
            let mut e = Json::obj();
            e.set("busy_us", Json::Num(u.busy_us))
                .set("idle_us", Json::Num(u.idle_us))
                .set("utilization", Json::Num(u.utilization()))
                .set("ops", Json::Num(u.ops as f64));
            obj.set(u.engine.name(), e);
        }
        obj
    }

    /// The schedule as Chrome trace events — the second renderer next
    /// to [`Self::render_timeline`], behind `simulate --trace-out`.
    ///
    /// One thread lane per engine of the config (in
    /// [`EngineConfig::engines`] display order, named via `thread_name`
    /// metadata), one complete slice per placed op with the op's
    /// cost-model tag as its category (suffixed `,critical` on the
    /// critical chain so viewers can highlight it). Slice `args` carry
    /// the op index, slack, and note. Zero-width ops occupy no engine
    /// and are skipped — same as the timeline's busy accounting.
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        let engines = self.config.engines();
        let mut events: Vec<TraceEvent> = Vec::with_capacity(self.ops.len() + engines.len() + 1);
        events.push(TraceEvent::process_name(
            1,
            &format!("schedule {} ({})", self.module_name, self.config.name()),
        ));
        for (tid, e) in engines.iter().enumerate() {
            events.push(TraceEvent::thread_name(1, tid as u64, e.name()));
        }
        for op in &self.ops {
            let Some(engine) = op.engine else { continue };
            let Some(tid) = engines.iter().position(|&e| e == engine) else {
                continue;
            };
            let cat = if op.critical() {
                format!("{},critical", op.source)
            } else {
                op.source.to_string()
            };
            let mut ev = TraceEvent::complete(
                &op.op_name,
                &cat,
                op.start_us,
                op.end_us - op.start_us,
                1,
                tid as u64,
            )
            .arg("index", Json::Num(op.index as f64))
            .arg("slack_us", Json::Num(op.slack_us))
            .arg("critical", Json::Bool(op.critical()));
            if !op.note.is_empty() {
                ev = ev.arg("note", Json::Str(op.note.clone()));
            }
            events.push(ev);
        }
        events
    }

    /// The full schedule (totals, engines, per-op rows) as one JSON
    /// object — the machine-readable form of [`Self::render_timeline`].
    pub fn to_json(&self) -> Json {
        let ops: Vec<Json> = self.ops.iter().map(ScheduledOp::to_json).collect();
        let mut j = Json::obj();
        j.set("module", Json::Str(self.module_name.clone()))
            .set("config", Json::Str(self.config.name().to_string()))
            .set("makespan_us", Json::Num(self.makespan_us))
            .set("critical_path_us", Json::Num(self.critical_path_us))
            .set("engines", self.engines_to_json())
            .set("ops", Json::Arr(ops));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(engine: Option<Engine>, cost: f64, preds: &[usize]) -> SchedNode {
        SchedNode {
            index: 0,
            op_name: "n".into(),
            engine,
            cost_us: cost,
            preds: preds.to_vec(),
            source: "free",
            note: String::new(),
        }
    }

    /// Diamond: a 10us MXU op and a 2us VPU op feed a 1us VPU op.
    fn diamond() -> Vec<SchedNode> {
        vec![
            node(Some(Engine::Mxu), 10.0, &[]),
            node(Some(Engine::Vpu), 2.0, &[]),
            node(Some(Engine::Vpu), 1.0, &[0, 1]),
        ]
    }

    #[test]
    fn critical_path_is_longest_chain() {
        assert_eq!(critical_path(&diamond()), 11.0);
        // A pure chain sums.
        let chain = vec![
            node(Some(Engine::Mxu), 3.0, &[]),
            node(Some(Engine::Vpu), 4.0, &[0]),
        ];
        assert_eq!(critical_path(&chain), 7.0);
        assert_eq!(critical_path(&[]), 0.0);
    }

    #[test]
    fn slack_and_usage_on_the_diamond() {
        let s = finish_schedule("d".into(), EngineConfig::Tpu, diamond());
        assert_eq!(s.makespan_us, 11.0);
        assert_eq!(s.critical_path_us, 11.0);
        // The MXU op and the join are critical; the small VPU op has
        // 8us of slack (it may finish any time before t=10).
        assert!(s.ops[0].critical());
        assert!(s.ops[2].critical());
        assert_eq!(s.ops[1].slack_us, 8.0);
        assert!(!s.ops[1].critical());
        let mxu = s.usage(Engine::Mxu).unwrap();
        assert_eq!(mxu.busy_us, 10.0);
        assert_eq!(mxu.idle_us, 1.0);
        assert_eq!(mxu.ops, 1);
        let vpu = s.usage(Engine::Vpu).unwrap();
        assert_eq!(vpu.busy_us, 3.0);
        assert_eq!(vpu.ops, 2);
        let dma = s.usage(Engine::Dma).unwrap();
        assert_eq!(dma.busy_us, 0.0);
        assert_eq!(dma.idle_us, 11.0);
        assert_eq!(dma.utilization(), 0.0);
    }

    #[test]
    fn timeline_renders_sorted_and_starred() {
        let s = finish_schedule("d".into(), EngineConfig::Tpu, diamond());
        let text = s.render_timeline();
        assert!(text.contains("makespan 11.000 us"));
        assert!(text.contains("critical path 11.000 us"));
        assert!(text.contains('*'), "critical ops must be starred");
        assert!(text.contains("engine mxu"));
        // Both roots start at 0; the join line comes last.
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[3].contains("10.000 ..    11.000"));
    }

    #[test]
    fn trace_events_lane_per_engine() {
        let s = finish_schedule("d".into(), EngineConfig::Tpu, diamond());
        let events = s.trace_events();
        // process_name + 4 engine lanes (tpu config) + 3 op slices.
        assert_eq!(events.len(), 1 + 4 + 3);
        assert_eq!(events[0].ph, 'M');
        assert_eq!(events[1].args.req_str("name").unwrap(), "mxu");
        let slices: Vec<&TraceEvent> = events.iter().filter(|e| e.ph == 'X').collect();
        assert_eq!(slices.len(), 3);
        // The 10us MXU root sits on lane 0 and is flagged critical.
        assert_eq!(slices[0].tid, 0);
        assert_eq!(slices[0].ts_us, 0.0);
        assert_eq!(slices[0].dur_us, Some(10.0));
        assert!(slices[0].cat.ends_with(",critical"));
        // The slack-y VPU op is on lane 1, uncritical, slack in args.
        assert_eq!(slices[1].tid, 1);
        assert_eq!(slices[1].cat, "free");
        assert_eq!(slices[1].args.req_f64("slack_us").unwrap(), 8.0);
        assert_eq!(slices[1].args.get("critical"), Some(&Json::Bool(false)));
    }

    #[test]
    fn json_shape() {
        let s = finish_schedule("d".into(), EngineConfig::Tpu, diamond());
        let j = s.to_json();
        assert_eq!(j.req_f64("makespan_us").unwrap(), 11.0);
        assert_eq!(j.req_str("config").unwrap(), "tpu");
        let ops = j.req_arr("ops").unwrap();
        assert_eq!(ops.len(), 3);
        assert_eq!(ops[0].req_str("engine").unwrap(), "mxu");
        let engines = j.get("engines").unwrap();
        assert_eq!(
            engines.get("vpu").unwrap().req_f64("busy_us").unwrap(),
            3.0
        );
    }
}
