//! Dependence-graph multi-engine scheduling.
//!
//! The plain estimator sums per-op latencies; a real TPU overlaps MXU
//! compute, VPU elementwise work, HBM DMA and ICI traffic. This
//! subsystem closes that gap:
//!
//! * [`dag`] — the SSA dependence DAG over a parsed function (and the
//!   shared result-id → producer map the fusion planner also uses);
//! * [`engine`] — the engine model: which hardware unit runs each op
//!   class, under three configurations (serialized baseline, the
//!   distributed compute+ICI pair, the full TPU set);
//! * [`schedule`] — the list scheduler placing costed ops onto engines;
//! * [`analysis`] — critical path, per-op slack, per-engine busy/idle
//!   breakdown and the serialized timeline;
//! * [`reuse`] — build-once / re-cost-many schedule templates
//!   ([`ScheduleTemplate`]): capture the topology + residency structure
//!   once, replay it over new per-op costs bit-identically to a
//!   from-scratch build.
//!
//! Invariants (property-tested in `tests/graph_schedule.rs`):
//! `critical_path_us <= makespan_us <= unfused sum`, and the serialized
//! single-engine schedule reproduces the unfused sum bit for bit.

pub mod analysis;
pub mod dag;
pub mod engine;
pub mod reuse;
pub mod schedule;

pub use analysis::{
    critical_path, op_bound, EngineUsage, ModuleSchedule, RooflineSummary, ScheduledOp,
};
pub use dag::{producer_map, DepGraph};
pub use engine::{Engine, EngineConfig};
pub use reuse::{OpCost, ScheduleTemplate};
pub use schedule::{place, schedule_estimate, schedule_module, Placement, SchedNode};
