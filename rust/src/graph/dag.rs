//! SSA dependence DAG over a parsed function.
//!
//! The frontend emits ops in SSA order, which is already topological:
//! every operand is produced by an earlier op or is a function argument.
//! [`producer_map`] is the single source of truth for "which op defines
//! this SSA value" — the fusion planner and the scheduler both build on
//! it instead of re-walking the op list.

use std::collections::HashMap;

use crate::frontend::opinfo::FuncInfo;

/// Map SSA result id (without `%`) to the index of the op producing it.
///
/// Function arguments never appear as keys: an operand that misses this
/// map is a free input with no intra-function dependence.
pub fn producer_map(func: &FuncInfo) -> HashMap<&str, usize> {
    let mut producer: HashMap<&str, usize> = HashMap::new();
    for (i, op) in func.ops.iter().enumerate() {
        for r in &op.results {
            producer.insert(r.as_str(), i);
        }
    }
    producer
}

/// The dependence DAG of one function: node `i` is `func.ops[i]`, and an
/// edge `p -> i` means op `i` consumes a value op `p` produces.
#[derive(Debug, Clone)]
pub struct DepGraph {
    /// For each op, the (deduplicated, operand-ordered) producer indices.
    pub preds: Vec<Vec<usize>>,
    /// For each op, the ops consuming any of its results.
    pub succs: Vec<Vec<usize>>,
}

impl DepGraph {
    /// Build the dependence DAG of one function.
    pub fn build(func: &FuncInfo) -> DepGraph {
        let producer = producer_map(func);
        let n = func.ops.len();
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, op) in func.ops.iter().enumerate() {
            for operand in &op.operands {
                if let Some(&p) = producer.get(operand.as_str()) {
                    // `p < i` always holds for well-formed SSA; the guard
                    // keeps a malformed module from producing a cycle.
                    if p < i && !preds[i].contains(&p) {
                        preds[i].push(p);
                        succs[p].push(i);
                    }
                }
            }
        }
        DepGraph { preds, succs }
    }

    /// Number of nodes (= ops in the function).
    pub fn len(&self) -> usize {
        self.preds.len()
    }

    /// True for an empty function.
    pub fn is_empty(&self) -> bool {
        self.preds.is_empty()
    }

    /// Ops with no intra-function dependences (sources of the DAG).
    pub fn roots(&self) -> impl Iterator<Item = usize> + '_ {
        self.preds
            .iter()
            .enumerate()
            .filter(|(_, p)| p.is_empty())
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_module;

    const DIAMOND: &str = r#"
module { func.func @main(%a: tensor<64x64xf32>) -> tensor<64x64xf32> {
  %0 = stablehlo.add %a, %a : tensor<64x64xf32>
  %1 = stablehlo.multiply %0, %a : tensor<64x64xf32>
  %2 = stablehlo.tanh %0 : tensor<64x64xf32>
  %3 = stablehlo.add %1, %2 : tensor<64x64xf32>
  return %3 : tensor<64x64xf32>
} }"#;

    #[test]
    fn builds_diamond_dependences() {
        let m = parse_module(DIAMOND).unwrap();
        let func = m.entry().unwrap();
        let g = DepGraph::build(func);
        assert_eq!(g.len(), 4);
        assert_eq!(g.preds[0], Vec::<usize>::new());
        assert_eq!(g.preds[1], vec![0]);
        assert_eq!(g.preds[2], vec![0]);
        assert_eq!(g.preds[3], vec![1, 2]);
        assert_eq!(g.succs[0], vec![1, 2]);
        assert_eq!(g.succs[3], Vec::<usize>::new());
        assert_eq!(g.roots().collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn repeated_operand_deduplicates() {
        let m = parse_module(
            r#"module { func.func @main(%a: tensor<8x8xf32>) -> tensor<8x8xf32> {
  %0 = stablehlo.add %a, %a : tensor<8x8xf32>
  %1 = stablehlo.multiply %0, %0 : tensor<8x8xf32>
  return %1 : tensor<8x8xf32>
} }"#,
        )
        .unwrap();
        let g = DepGraph::build(m.entry().unwrap());
        assert_eq!(g.preds[1], vec![0], "duplicate edge not collapsed");
        assert_eq!(g.succs[0], vec![1]);
    }

    #[test]
    fn producer_map_covers_all_results() {
        let m = parse_module(DIAMOND).unwrap();
        let func = m.entry().unwrap();
        let p = producer_map(func);
        assert_eq!(p.len(), 4);
        assert_eq!(p.get("0"), Some(&0));
        assert_eq!(p.get("3"), Some(&3));
        assert_eq!(p.get("a"), None, "arguments have no producer");
    }
}
