//! The multi-engine list scheduler.
//!
//! Ops (already costed by the [`Estimator`]) are placed onto the engines
//! of an [`EngineConfig`] in program order — which is topological for
//! SSA — with the classic list-scheduling rule: an op starts when its
//! operands are ready *and* its engine is free. Two invariants anchor
//! the result (both follow from the monotonicity of `max`/`+` on
//! non-negative floats, so they hold *exactly*, not just within an
//! epsilon — property-tested in `tests/graph_schedule.rs`):
//!
//! * `critical_path_us <= makespan_us` — the dependence-only relaxation
//!   can never exceed the resource-constrained schedule;
//! * `makespan_us <=` the unfused program-order sum — overlap can only
//!   help; with [`EngineConfig::Serialized`] the makespan *equals* the
//!   unfused sum bit for bit.

use crate::coordinator::estimator::{Estimator, ModelEstimate};
use crate::frontend::classify::classify;
use crate::frontend::opinfo::{ModuleInfo, OpInfo};

use super::analysis::{finish_schedule, ModuleSchedule};
use super::dag::DepGraph;
use super::engine::{Engine, EngineConfig};

/// One schedulable unit: a costed op (or synthetic segment, e.g. the
/// implicit all-gather a model-parallel GEMM pays) plus its dependences.
#[derive(Debug, Clone)]
pub struct SchedNode {
    /// Index of the source op within its function (synthetic nodes reuse
    /// their producer's index).
    pub index: usize,
    /// Display name of the op (or synthetic segment).
    pub op_name: String,
    /// `None` = zero-width: finishes the instant its operands are ready.
    pub engine: Option<Engine>,
    /// Time the node occupies its engine, µs.
    pub cost_us: f64,
    /// Node ids (positions in the node list) this node depends on; every
    /// entry must be smaller than the node's own position.
    pub preds: Vec<usize>,
    /// Which cost model produced `cost_us` (an [`EstimateSource`] tag,
    /// or `"call"` for inlined sub-functions).
    ///
    /// [`EstimateSource`]: crate::coordinator::EstimateSource
    pub source: &'static str,
    /// Shape/context note carried from the estimate.
    pub note: String,
}

/// Where one node landed on the timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    /// Placed start time, µs.
    pub start_us: f64,
    /// Placed finish time, µs.
    pub end_us: f64,
}

/// The moment all of a node's predecessors have finished.
pub(crate) fn ready_time(preds: &[usize], finished: &[Placement]) -> f64 {
    preds
        .iter()
        .fold(0.0f64, |acc, &p| acc.max(finished[p].end_us))
}

/// Greedy in-order list schedule over topologically sorted nodes.
///
/// Panics if a node depends on a later node (the builder APIs in this
/// module only produce forward edges).
pub fn place(nodes: &[SchedNode]) -> Vec<Placement> {
    let mut lane_free = [0.0f64; Engine::ALL.len()];
    let mut placed: Vec<Placement> = Vec::with_capacity(nodes.len());
    for node in nodes {
        let ready = ready_time(&node.preds, &placed);
        let start = match node.engine {
            Some(e) => ready.max(lane_free[e.lane()]),
            None => ready,
        };
        let end = start + node.cost_us;
        if let Some(e) = node.engine {
            lane_free[e.lane()] = end;
        }
        placed.push(Placement {
            start_us: start,
            end_us: end,
        });
    }
    placed
}

/// An inlined call into a private sub-function (mirrors the condition
/// `Estimator::estimate_func` uses at entry depth): the estimate row
/// holds the callee's whole inlined cost, and the scheduler treats it
/// as one opaque compute block. Shared with the memory-aware expansion
/// in [`crate::memory`], which must route calls identically.
pub(crate) fn is_inlined_call(op: &OpInfo) -> bool {
    (op.short_name() == "call" || op.op_name == "func.call") && op.callee.is_some()
}

/// Schedule a whole module's entry function onto `config`'s engines.
///
/// Costs each op through `est` (and therefore through the shape cache)
/// via one `estimate_module` walk. Callers that already hold the
/// unfused [`ModelEstimate`] should use [`schedule_estimate`] instead —
/// it reuses those per-op costs and leaves the cache counters alone.
pub fn schedule_module(
    est: &Estimator,
    module: &ModuleInfo,
    config: EngineConfig,
) -> ModuleSchedule {
    let report = est.estimate_module(module);
    schedule_estimate(module, &report, config)
}

/// Schedule a module from its already-computed unfused estimate: the
/// `report` rows (one per entry-function op, calls inlined as single
/// rows) supply every cost, so no re-estimation — and no cache-counter
/// traffic — happens here. The serialized config reproduces
/// `report.total_us` bit for bit.
pub fn schedule_estimate(
    module: &ModuleInfo,
    report: &ModelEstimate,
    config: EngineConfig,
) -> ModuleSchedule {
    let Some(func) = module.entry() else {
        return finish_schedule(module.name.clone(), config, Vec::new());
    };
    debug_assert_eq!(
        report.ops.len(),
        func.ops.len(),
        "estimate rows must align 1:1 with the entry function's ops"
    );
    let graph = DepGraph::build(func);
    let mut nodes: Vec<SchedNode> = Vec::with_capacity(func.ops.len());
    for ((i, op), row) in func.ops.iter().enumerate().zip(&report.ops) {
        let engine = if is_inlined_call(op) {
            // The row is the callee's whole inlined timeline: an opaque
            // compute block (never zero-width, never ICI).
            Some(match config {
                EngineConfig::Serialized => Engine::Unified,
                _ => Engine::Mxu,
            })
        } else {
            config.engine_of(&classify(op))
        };
        nodes.push(SchedNode {
            index: row.index,
            op_name: row.op_name.clone(),
            engine,
            cost_us: row.latency_us,
            preds: graph.preds[i].clone(),
            source: row.source.tag(),
            note: row.note.clone(),
        });
    }
    finish_schedule(module.name.clone(), config, nodes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::frontend::parse_module;
    use crate::scalesim::{GemmShape, ScaleConfig};

    fn estimator() -> Estimator {
        let mut obs = Vec::new();
        for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
            let g = GemmShape::new(d, d, d);
            obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
        }
        Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
    }

    fn node(engine: Option<Engine>, cost: f64, preds: &[usize]) -> SchedNode {
        SchedNode {
            index: 0,
            op_name: "n".into(),
            engine,
            cost_us: cost,
            preds: preds.to_vec(),
            source: "free",
            note: String::new(),
        }
    }

    #[test]
    fn independent_ops_on_distinct_engines_overlap() {
        let nodes = vec![
            node(Some(Engine::Mxu), 10.0, &[]),
            node(Some(Engine::Vpu), 4.0, &[]),
            node(Some(Engine::Vpu), 3.0, &[0]),
        ];
        let p = place(&nodes);
        assert_eq!(p[0].start_us, 0.0);
        assert_eq!(p[1].start_us, 0.0, "vpu op should not wait for mxu");
        // Node 2 waits for its MXU producer, then for the VPU lane
        // (already free at 4.0), so the dependence dominates.
        assert_eq!(p[2].start_us, 10.0);
        assert_eq!(p[2].end_us, 13.0);
    }

    #[test]
    fn same_engine_serializes_even_without_dependences() {
        let nodes = vec![
            node(Some(Engine::Mxu), 5.0, &[]),
            node(Some(Engine::Mxu), 5.0, &[]),
        ];
        let p = place(&nodes);
        assert_eq!(p[1].start_us, 5.0);
        assert_eq!(p[1].end_us, 10.0);
    }

    #[test]
    fn zero_width_nodes_finish_at_ready_time() {
        let nodes = vec![
            node(Some(Engine::Mxu), 7.0, &[]),
            node(None, 0.0, &[0]),
            node(Some(Engine::Vpu), 1.0, &[1]),
        ];
        let p = place(&nodes);
        assert_eq!(p[1].start_us, 7.0);
        assert_eq!(p[1].end_us, 7.0);
        assert_eq!(p[2].start_us, 7.0);
    }

    #[test]
    fn serialized_schedule_matches_unfused_sum_bitwise() {
        let text = r#"
module @m { func.func @main(%x: tensor<256x256xf32>, %w: tensor<256x256xf32>) -> tensor<256x256xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<256x256xf32>, tensor<256x256xf32>) -> tensor<256x256xf32>
  %1 = stablehlo.add %0, %x : tensor<256x256xf32>
  %2 = stablehlo.transpose %1, dims = [1, 0] : (tensor<256x256xf32>) -> tensor<256x256xf32>
  %3 = stablehlo.dot_general %2, %w, contracting_dims = [1] x [0] : (tensor<256x256xf32>, tensor<256x256xf32>) -> tensor<256x256xf32>
  return %3 : tensor<256x256xf32>
} }"#;
        let est = estimator();
        let module = parse_module(text).unwrap();
        let unfused = est.estimate_module(&module);
        let sched = schedule_module(&est, &module, EngineConfig::Serialized);
        assert_eq!(sched.makespan_us.to_bits(), unfused.total_us.to_bits());
        assert_eq!(sched.ops.len(), unfused.ops.len());
        // One lane: starts are non-decreasing in program order.
        for w in sched.ops.windows(2) {
            assert!(w[1].start_us >= w[0].start_us);
        }
    }

    #[test]
    fn call_rows_schedule_as_opaque_compute_blocks() {
        let text = r#"
module @m {
  func.func @main(%x: tensor<128x128xf32>) -> tensor<128x128xf32> {
    %0 = func.call @helper(%x) : (tensor<128x128xf32>) -> tensor<128x128xf32>
    %1 = stablehlo.add %0, %x : tensor<128x128xf32>
    return %1 : tensor<128x128xf32>
  }
  func.func private @helper(%a: tensor<128x128xf32>) -> tensor<128x128xf32> {
    %0 = stablehlo.dot_general %a, %a, contracting_dims = [1] x [0] : (tensor<128x128xf32>, tensor<128x128xf32>) -> tensor<128x128xf32>
    %1 = stablehlo.tanh %0 : tensor<128x128xf32>
    return %1 : tensor<128x128xf32>
  }
}"#;
        let est = estimator();
        let module = parse_module(text).unwrap();
        let unfused = est.estimate_module(&module);
        assert_eq!(unfused.ops.len(), 2, "call should inline as one row");
        let serialized = schedule_module(&est, &module, EngineConfig::Serialized);
        assert_eq!(serialized.makespan_us.to_bits(), unfused.total_us.to_bits());
        let tpu = schedule_module(&est, &module, EngineConfig::Tpu);
        assert_eq!(tpu.ops[0].engine, Some(Engine::Mxu), "call is an opaque block");
        assert!(tpu.ops[0].op_name.starts_with("call @helper"));
        assert!(tpu.ops[0].latency_us > 0.0);
        assert!(tpu.makespan_us <= unfused.total_us);
    }

    #[test]
    fn schedule_estimate_reuses_rows_without_cache_traffic() {
        let text = r#"
module @m { func.func @main(%x: tensor<256x256xf32>, %w: tensor<256x256xf32>) -> tensor<256x256xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<256x256xf32>, tensor<256x256xf32>) -> tensor<256x256xf32>
  %1 = stablehlo.add %0, %x : tensor<256x256xf32>
  return %1 : tensor<256x256xf32>
} }"#;
        let est = estimator();
        let module = parse_module(text).unwrap();
        let report = est.estimate_module(&module);
        let before = est.cache.stats();
        let sched = schedule_estimate(&module, &report, EngineConfig::Tpu);
        let after = est.cache.stats();
        assert_eq!(
            (before.hits, before.misses),
            (after.hits, after.misses),
            "schedule_estimate must not touch the cache"
        );
        assert_eq!(sched.ops.len(), 2);
        // Row costs are carried over verbatim.
        assert_eq!(
            sched.ops[0].latency_us.to_bits(),
            report.ops[0].latency_us.to_bits()
        );
        assert_eq!(sched.ops[1].note, report.ops[1].note);
        // And the serialized variant is the unfused sum, bitwise.
        let ser = schedule_estimate(&module, &report, EngineConfig::Serialized);
        assert_eq!(ser.makespan_us.to_bits(), report.total_us.to_bits());
    }

    #[test]
    fn tpu_schedule_overlaps_independent_engines() {
        // The transpose (DMA) of an argument is independent of the dot
        // (MXU), so the tpu schedule must beat the serialized sum.
        let text = r#"
module @m { func.func @main(%x: tensor<1024x1024xf32>, %w: tensor<1024x1024xf32>) -> tensor<1024x1024xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<1024x1024xf32>, tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
  %1 = stablehlo.transpose %w, dims = [1, 0] : (tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
  %2 = stablehlo.add %0, %1 : tensor<1024x1024xf32>
  return %2 : tensor<1024x1024xf32>
} }"#;
        let est = estimator();
        let module = parse_module(text).unwrap();
        let unfused = est.estimate_module(&module);
        let sched = schedule_module(&est, &module, EngineConfig::Tpu);
        assert!(
            sched.makespan_us < unfused.total_us,
            "no overlap: {} vs {}",
            sched.makespan_us,
            unfused.total_us
        );
        assert!(sched.critical_path_us <= sched.makespan_us);
        // The add depends on both, so it is last and critical.
        let add = &sched.ops[2];
        assert_eq!(add.end_us.to_bits(), sched.makespan_us.to_bits());
        assert_eq!(add.slack_us, 0.0);
    }
}
