//! Inter-chip interconnect (ICI) collective cost model.
//!
//! TPU slices connect chips with dedicated ICI links arranged as a ring
//! or a 2-D torus. Collectives are costed with the classic alpha-beta
//! model: a schedule of `steps` link hops, each paying a fixed per-hop
//! latency `alpha` (µs), plus a bandwidth term — the bytes each chip must
//! push through its links divided by the effective link bandwidth `beta`
//! (bytes/µs). The formulas are the standard ring-algorithm costs
//! (Chan et al., "Collective communication: theory, practice, and
//! experience"); a 2-D torus shortens the latency term to the sum of the
//! per-dimension ring lengths and doubles usable bandwidth (one
//! concurrent ring per torus dimension). See DESIGN.md §Multi-chip
//! slices for the assumptions.

use anyhow::{bail, Result};

use crate::frontend::classify::CollectiveKind;

/// Default per-link bandwidth, GB/s (order of a TPU v4 ICI link pair).
pub const DEFAULT_LINK_GBPS: f64 = 100.0;

/// Default per-hop latency, µs.
pub const DEFAULT_HOP_LATENCY_US: f64 = 1.0;

/// Physical arrangement of the slice's ICI links.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IciTopology {
    /// A single bidirectional ring over all chips.
    Ring,
    /// A 2-D torus of `x * y` chips (rings in both dimensions).
    Torus2D {
        /// Chips along the first torus axis.
        x: usize,
        /// Chips along the second torus axis.
        y: usize,
    },
}

impl IciTopology {
    /// Parse a CLI/service spelling: `ring`, `torus` (auto-factored into
    /// a near-square grid), or an explicit `XxY`.
    pub fn parse(spec: &str, chips: usize) -> Result<IciTopology> {
        match spec {
            "ring" => Ok(IciTopology::Ring),
            "torus" | "torus2d" | "2d" => Ok(IciTopology::torus(chips)),
            dims => {
                let Some((xs, ys)) = dims.split_once('x') else {
                    bail!("unknown ICI topology '{spec}' (ring|torus|XxY)");
                };
                let (x, y): (usize, usize) = match (xs.parse(), ys.parse()) {
                    (Ok(x), Ok(y)) => (x, y),
                    _ => bail!("bad torus spec '{spec}' (expected XxY)"),
                };
                if x * y != chips {
                    bail!("torus {x}x{y} holds {} chips, slice has {chips}", x * y);
                }
                Ok(IciTopology::Torus2D { x, y })
            }
        }
    }

    /// The near-square 2-D torus for `chips` chips.
    pub fn torus(chips: usize) -> IciTopology {
        let chips = chips.max(1);
        let mut x = (chips as f64).sqrt().floor() as usize;
        x = x.max(1);
        while x > 1 && chips % x != 0 {
            x -= 1;
        }
        IciTopology::Torus2D { x, y: chips / x }
    }

    /// Number of chips the topology wires up (ring adapts to any count).
    pub fn chips_or(&self, slice_chips: usize) -> usize {
        match self {
            IciTopology::Ring => slice_chips,
            IciTopology::Torus2D { x, y } => x * y,
        }
    }

    /// Ring-schedule step count for reduce/gather-style collectives.
    fn reduce_steps(&self, chips: usize) -> u64 {
        match self {
            IciTopology::Ring => chips.saturating_sub(1) as u64,
            IciTopology::Torus2D { x, y } => {
                (x.saturating_sub(1) + y.saturating_sub(1)) as u64
            }
        }
    }

    /// Concurrent rings (bandwidth multiplier): a torus streams along
    /// both dimensions at once — unless one dimension is degenerate, in
    /// which case it is physically a ring and earns no extra links.
    fn ports(&self) -> f64 {
        match self {
            IciTopology::Ring => 1.0,
            IciTopology::Torus2D { x, y } if *x <= 1 || *y <= 1 => 1.0,
            IciTopology::Torus2D { .. } => 2.0,
        }
    }
}

impl std::fmt::Display for IciTopology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IciTopology::Ring => f.write_str("ring"),
            IciTopology::Torus2D { x, y } => write!(f, "{x}x{y} torus"),
        }
    }
}

/// A multi-chip slice: how many chips, how they are wired, and how fast
/// the wires are.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SliceConfig {
    /// Chips in the slice.
    pub chips: usize,
    /// Physical link arrangement.
    pub topology: IciTopology,
    /// Per-link bandwidth in GB/s.
    pub link_gbps: f64,
    /// Per-hop latency (the alpha term), µs.
    pub hop_latency_us: f64,
}

impl SliceConfig {
    /// A ring slice with the default hop latency.
    pub fn ring(chips: usize, link_gbps: f64) -> SliceConfig {
        SliceConfig {
            chips,
            topology: IciTopology::Ring,
            link_gbps,
            hop_latency_us: DEFAULT_HOP_LATENCY_US,
        }
    }

    /// The degenerate one-chip slice (no ICI traffic at all).
    pub fn single_chip() -> SliceConfig {
        SliceConfig::ring(1, DEFAULT_LINK_GBPS)
    }

    /// A validated slice wired with a device's ICI parameters and
    /// default topology (delegates to
    /// [`DeviceSpec::slice_config`](crate::device::DeviceSpec::slice_config)).
    pub fn for_device(spec: &crate::device::DeviceSpec, chips: usize) -> Result<SliceConfig> {
        spec.slice_config(chips, None)
    }

    /// Reject inconsistent chip counts / non-positive link parameters.
    pub fn validate(&self) -> Result<()> {
        if self.chips == 0 {
            bail!("slice needs at least one chip");
        }
        if !(self.link_gbps.is_finite() && self.link_gbps > 0.0) {
            bail!("link bandwidth must be positive, got {}", self.link_gbps);
        }
        if !(self.hop_latency_us.is_finite() && self.hop_latency_us >= 0.0) {
            bail!("hop latency must be non-negative, got {}", self.hop_latency_us);
        }
        if self.topology.chips_or(self.chips) != self.chips {
            bail!(
                "topology {} wires {} chips, slice has {}",
                self.topology,
                self.topology.chips_or(self.chips),
                self.chips
            );
        }
        Ok(())
    }
}

/// The alpha-beta collective cost model over one [`SliceConfig`].
pub struct IciModel {
    slice: SliceConfig,
}

impl IciModel {
    /// A collective model for one slice.
    pub fn new(slice: &SliceConfig) -> IciModel {
        IciModel { slice: *slice }
    }

    /// Effective bytes/µs each chip can stream through its ICI ports
    /// (1 GB/s = 1000 bytes/µs).
    fn bytes_per_us(&self) -> f64 {
        self.slice.link_gbps * 1e3 * self.slice.topology.ports()
    }

    /// Cost one collective in µs. `bytes_in` is the operand payload each
    /// chip contributes, `bytes_out` the result each chip ends up with
    /// (they differ for all-gather / reduce-scatter).
    pub fn collective_us(&self, kind: CollectiveKind, bytes_in: u64, bytes_out: u64) -> f64 {
        let chips = self.slice.chips;
        if chips <= 1 {
            return 0.0;
        }
        let p = chips as f64;
        let steps = self.slice.topology.reduce_steps(chips) as f64;
        let alpha = self.slice.hop_latency_us;
        let bw = self.bytes_per_us();
        match kind {
            // Ring all-reduce = reduce-scatter + all-gather: 2(P-1) steps,
            // 2(P-1)/P of the payload over the wire.
            CollectiveKind::AllReduce => {
                2.0 * steps * alpha + 2.0 * (p - 1.0) / p * bytes_in as f64 / bw
            }
            CollectiveKind::ReduceScatter => {
                steps * alpha + (p - 1.0) / p * bytes_in as f64 / bw
            }
            // Each chip must receive (P-1)/P of the gathered result.
            CollectiveKind::AllGather => {
                steps * alpha + (p - 1.0) / p * bytes_out as f64 / bw
            }
            // One neighbour hop over a single link (no ring parallelism).
            CollectiveKind::CollectivePermute => {
                alpha + bytes_in as f64 / (self.slice.link_gbps * 1e3)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_chip_is_free() {
        let m = IciModel::new(&SliceConfig::single_chip());
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::CollectivePermute,
        ] {
            assert_eq!(m.collective_us(kind, 1 << 20, 1 << 20), 0.0);
        }
    }

    #[test]
    fn all_reduce_formula() {
        // 4 chips, ring, 100 GB/s, 1 us/hop, 4 MiB payload.
        let m = IciModel::new(&SliceConfig::ring(4, 100.0));
        let bytes = 4u64 << 20;
        let got = m.collective_us(CollectiveKind::AllReduce, bytes, bytes);
        let want = 2.0 * 3.0 * 1.0 + 2.0 * (3.0 / 4.0) * bytes as f64 / 100e3;
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        // All-reduce = reduce-scatter + all-gather.
        let rs = m.collective_us(CollectiveKind::ReduceScatter, bytes, bytes / 4);
        let ag = m.collective_us(CollectiveKind::AllGather, bytes / 4, bytes);
        assert!((got - (rs + ag)).abs() < 1e-9);
    }

    #[test]
    fn costs_monotone_in_bandwidth_and_payload() {
        let kinds = [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::CollectivePermute,
        ];
        for kind in kinds {
            let mut last = f64::INFINITY;
            for gbps in [10.0, 50.0, 100.0, 400.0] {
                let m = IciModel::new(&SliceConfig::ring(8, gbps));
                let t = m.collective_us(kind, 1 << 24, 1 << 24);
                assert!(t <= last, "{kind} not monotone in bandwidth");
                last = t;
            }
            let m = IciModel::new(&SliceConfig::ring(8, 100.0));
            assert!(
                m.collective_us(kind, 1 << 24, 1 << 24)
                    >= m.collective_us(kind, 1 << 20, 1 << 20)
            );
        }
    }

    #[test]
    fn torus_beats_ring_for_large_slices() {
        let bytes = 64u64 << 20;
        let ring = IciModel::new(&SliceConfig::ring(16, 100.0));
        let torus = IciModel::new(&SliceConfig {
            chips: 16,
            topology: IciTopology::torus(16),
            link_gbps: 100.0,
            hop_latency_us: DEFAULT_HOP_LATENCY_US,
        });
        assert!(
            torus.collective_us(CollectiveKind::AllReduce, bytes, bytes)
                < ring.collective_us(CollectiveKind::AllReduce, bytes, bytes)
        );
    }

    #[test]
    fn degenerate_torus_is_a_ring() {
        // A 1xN torus has no second dimension of links: same cost as a
        // ring of N chips.
        let bytes = 8u64 << 20;
        let ring = IciModel::new(&SliceConfig::ring(8, 100.0));
        let flat = IciModel::new(&SliceConfig {
            chips: 8,
            topology: IciTopology::Torus2D { x: 1, y: 8 },
            link_gbps: 100.0,
            hop_latency_us: DEFAULT_HOP_LATENCY_US,
        });
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::AllGather,
            CollectiveKind::ReduceScatter,
            CollectiveKind::CollectivePermute,
        ] {
            assert_eq!(
                ring.collective_us(kind, bytes, bytes).to_bits(),
                flat.collective_us(kind, bytes, bytes).to_bits(),
                "{kind}"
            );
        }
    }

    #[test]
    fn topology_parsing() {
        assert_eq!(IciTopology::parse("ring", 8).unwrap(), IciTopology::Ring);
        assert_eq!(
            IciTopology::parse("torus", 16).unwrap(),
            IciTopology::Torus2D { x: 4, y: 4 }
        );
        assert_eq!(
            IciTopology::parse("2x4", 8).unwrap(),
            IciTopology::Torus2D { x: 2, y: 4 }
        );
        assert!(IciTopology::parse("3x3", 8).is_err());
        assert!(IciTopology::parse("blob", 8).is_err());
        // Auto-factoring prefers near-square grids.
        assert_eq!(IciTopology::torus(12), IciTopology::Torus2D { x: 3, y: 4 });
        assert_eq!(IciTopology::torus(7), IciTopology::Torus2D { x: 1, y: 7 });
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(SliceConfig::ring(0, 100.0).validate().is_err());
        assert!(SliceConfig::ring(4, 0.0).validate().is_err());
        assert!(SliceConfig::ring(4, f64::NAN).validate().is_err());
        let mut bad = SliceConfig::ring(8, 100.0);
        bad.topology = IciTopology::Torus2D { x: 2, y: 2 };
        assert!(bad.validate().is_err());
        assert!(SliceConfig::ring(8, 100.0).validate().is_ok());
    }
}
