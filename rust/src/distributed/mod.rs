//! Multi-chip distributed simulation: slices, ICI collectives, and the
//! per-chip timeline estimator.
//!
//! Extends the single-chip estimator to an `N`-chip TPU slice: systolic
//! ops shard across chips via the SCALE-Sim multi-core partitioning
//! machinery, collectives are costed by an alpha-beta ICI model
//! ([`ici`]), and a two-engine per-chip timeline overlaps collectives
//! with independent compute ([`slice`]). A 1-chip slice reproduces the
//! single-chip estimate bit for bit.

pub mod ici;
pub mod slice;

pub use ici::{
    IciModel, IciTopology, SliceConfig, DEFAULT_HOP_LATENCY_US, DEFAULT_LINK_GBPS,
};
pub use slice::{
    estimate_gemm_sliced, estimate_module_distributed, estimate_module_distributed_memory,
    DistOpEstimate, DistributedEstimate, GemmSliceReport,
};
