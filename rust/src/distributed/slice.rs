//! Slice-level (multi-chip) module estimation.
//!
//! The distributed estimator runs a StableHLO module across an `N`-chip
//! slice under the SPMD assumptions XLA's GSPMD partitioner uses:
//!
//! * Tensors are row-sharded (leading axis split across chips) unless an
//!   `mhlo.sharding` annotation says otherwise; weights are replicated.
//! * Systolic ops shard along M (row-parallel) via the same
//!   [`split_dim`] machinery the multi-core partitioner uses; each chip
//!   simulates its largest shard (SPMD chips are symmetric, so the
//!   critical chip's timeline is the slice's timeline).
//! * A `{devices=[1,N]}`-annotated GEMM (model parallelism) shards along
//!   N instead and pays an implicit all-gather of its output to restore
//!   the row-sharded layout.
//! * Explicit collectives (`all_reduce`, `all_gather`, `reduce_scatter`,
//!   `collective_permute`) are costed by the [`IciModel`].
//!
//! Each chip is modeled with the generic dependence-graph scheduler
//! from [`crate::graph`] under its compute+ICI engine configuration
//! ([`crate::graph::EngineConfig::ComputeIci`]) — one compute lane
//! (MXU/VPU) plus the ICI lane: a
//! collective occupies the ICI engine and overlaps with any later
//! compute that does not consume its result. A model-parallel GEMM's
//! implicit all-gather becomes a synthetic ICI node depending on the
//! GEMM, and downstream consumers depend on the gather. On a 1-chip
//! slice every collective costs zero and the timeline degenerates to
//! the plain op sum, so the result is bit-identical to
//! [`Estimator::estimate_module`] (tested).

use crate::coordinator::cache::{CachedCost, ShapeKey};
use crate::coordinator::estimator::{EstimateSource, Estimator};
use crate::frontend::classify::{classify, CollectiveKind, OpClass};
use crate::frontend::opinfo::{ModuleInfo, ShardingAttr};
use crate::graph::analysis::critical_path;
use crate::graph::{place, DepGraph, Engine, SchedNode};
use crate::memory::timeline::push_unique;
use crate::memory::{DmaTimeline, FetchDma, MemoryConfig, RetireDma};
use crate::scalesim::partition::split_dim;
use crate::scalesim::topology::GemmShape;

use super::ici::{IciModel, SliceConfig};

/// Per-op row of a distributed estimate.
#[derive(Debug, Clone)]
pub struct DistOpEstimate {
    /// Index of the source op within its function.
    pub index: usize,
    /// Display name (calls render as `call @callee`).
    pub op_name: String,
    /// Compute-engine time for this op's shard, µs.
    pub compute_us: f64,
    /// ICI-engine time (explicit collective or implicit all-gather), µs.
    pub collective_us: f64,
    /// HBM DMA time behind this op (memory-aware walks only; zero when
    /// the slice was estimated without a [`MemoryConfig`]), µs.
    pub dma_us: f64,
    /// Timeline start of the op, µs.
    pub start_us: f64,
    /// Timeline completion of the op's results, µs.
    pub finish_us: f64,
    /// Sharding / collective context note.
    pub note: String,
}

/// Whole-module estimate across a slice (per-chip view; SPMD chips are
/// symmetric).
#[derive(Debug, Clone)]
pub struct DistributedEstimate {
    /// Module the estimate covers.
    pub module_name: String,
    /// The slice this was estimated for.
    pub slice: SliceConfig,
    /// Per-chip makespan: when the last engine goes idle, µs.
    pub total_us: f64,
    /// Per-chip busy time on the compute engine, µs.
    pub compute_us: f64,
    /// Per-chip busy time on the ICI engine, µs.
    pub collective_us: f64,
    /// Per-chip busy time on the HBM DMA engine (memory-aware walks
    /// only; zero otherwise), µs.
    pub dma_us: f64,
    /// Longest dependence chain ignoring engine contention, µs: no
    /// overlap schedule on this slice can finish faster.
    pub critical_path_us: f64,
    /// The same module estimated on one chip (the baseline).
    pub single_chip_us: f64,
    /// Per-op rows in program order.
    pub ops: Vec<DistOpEstimate>,
}

/// Parallel efficiency `T1 / (P * TP)`, clamped into `(0, 1]` (shard
/// regime shifts can make the cycle-accurate model superlinear; the
/// clamp keeps those artifacts from reading as >100%).
fn efficiency(single_us: f64, chips: usize, total_us: f64) -> f64 {
    if total_us <= 0.0 {
        return 1.0;
    }
    let e = single_us / (chips as f64 * total_us);
    e.min(1.0).max(f64::MIN_POSITIVE)
}

impl DistributedEstimate {
    /// Speedup over the single-chip estimate.
    pub fn speedup(&self) -> f64 {
        if self.total_us <= 0.0 {
            1.0
        } else {
            self.single_chip_us / self.total_us
        }
    }

    /// Parallel efficiency `T1 / (P * TP)` in `(0, 1]`.
    pub fn parallel_efficiency(&self) -> f64 {
        efficiency(self.single_chip_us, self.slice.chips, self.total_us)
    }

    /// Collective time hidden under compute by the overlap model, µs.
    pub fn overlapped_us(&self) -> f64 {
        (self.compute_us + self.collective_us - self.total_us).max(0.0)
    }

    /// The per-chip timeline as Chrome trace events, three lanes:
    /// `compute` (tid 0), `ici` (tid 1) and `dma` (tid 2).
    ///
    /// The distributed rows only keep each op's start/finish bracket, so
    /// the lanes are an approximation of the internal schedule: compute
    /// is drawn from the op's start, the collective is drawn ending at
    /// its finish (a collective completes its op), and DMA is drawn from
    /// the start. Zero-width components draw nothing.
    pub fn trace_events(&self) -> Vec<crate::obs::TraceEvent> {
        use crate::obs::TraceEvent;
        use crate::util::json::Json;
        let mut events = vec![
            TraceEvent::process_name(
                1,
                &format!("slice {} ({} chips)", self.module_name, self.slice.chips),
            ),
            TraceEvent::thread_name(1, 0, "compute"),
            TraceEvent::thread_name(1, 1, "ici"),
            TraceEvent::thread_name(1, 2, "dma"),
        ];
        for op in &self.ops {
            let mut slice = |tid: u64, cat: &str, ts: f64, dur: f64| {
                if dur > 0.0 {
                    let mut ev = TraceEvent::complete(&op.op_name, cat, ts, dur, 1, tid)
                        .arg("index", Json::Num(op.index as f64));
                    if !op.note.is_empty() {
                        ev = ev.arg("note", Json::Str(op.note.clone()));
                    }
                    events.push(ev);
                }
            };
            slice(0, "compute", op.start_us, op.compute_us);
            slice(1, "ici", op.finish_us - op.collective_us, op.collective_us);
            slice(2, "dma", op.start_us, op.dma_us);
        }
        events
    }
}

/// Estimate `module` across `slice`, reusing `est`'s calibrated models
/// and shape cache for every shard.
pub fn estimate_module_distributed(
    est: &Estimator,
    module: &ModuleInfo,
    slice: &SliceConfig,
) -> DistributedEstimate {
    let single = est.estimate_module(module);
    let mut out = walk_func(
        est,
        module,
        module.entry().map(|f| f.name.as_str()),
        slice,
        0,
        None,
    );
    out.single_chip_us = single.total_us;
    out
}

/// Memory-aware variant of [`estimate_module_distributed`]: threads a
/// [`DmaTimeline`] through each per-chip timeline, so every op's cold
/// operand shards pay HBM traffic on the DMA engine next to the compute
/// and ICI lanes. Footprints are the per-chip shards (full tensor bytes
/// divided across the slice). With [`MemoryConfig::infinite`] the walk
/// reproduces the memory-blind estimate bit for bit (tested in
/// `tests/memory_model.rs`).
pub fn estimate_module_distributed_memory(
    est: &Estimator,
    module: &ModuleInfo,
    slice: &SliceConfig,
    memory: &MemoryConfig,
) -> DistributedEstimate {
    let single = est.estimate_module(module);
    let mut out = walk_func(
        est,
        module,
        module.entry().map(|f| f.name.as_str()),
        slice,
        0,
        Some(memory),
    );
    out.single_chip_us = single.total_us;
    out
}

/// One GEMM across a slice (the `serve` gemm-request and CLI path).
#[derive(Debug, Clone, Copy)]
pub struct GemmSliceReport {
    /// Chips in the slice.
    pub chips: usize,
    /// Per-chip compute time of the sharded GEMM, µs.
    pub compute_us: f64,
    /// Implicit all-gather time, µs (0 for row-parallel shards).
    pub collective_us: f64,
    /// The same GEMM estimated on one chip, µs.
    pub single_chip_us: f64,
}

impl GemmSliceReport {
    /// Per-chip total: compute plus collective, µs.
    pub fn total_us(&self) -> f64 {
        self.compute_us + self.collective_us
    }

    /// Parallel efficiency `T1 / (P * TP)` in `(0, 1]`.
    pub fn parallel_efficiency(&self) -> f64 {
        efficiency(self.single_chip_us, self.chips, self.total_us())
    }
}

/// Estimate one GEMM sharded across the slice (auto axis, no sharding
/// annotation available).
pub fn estimate_gemm_sliced(
    est: &Estimator,
    gemm: GemmShape,
    slice: &SliceConfig,
) -> GemmSliceReport {
    let class = OpClass::SystolicGemm { gemm, count: 1 };
    let single = est.estimate_op(0, "gemm", &class).latency_us;
    let (sharded, gather) = shard_class(&class, None, None, slice.chips);
    let compute = est.estimate_op(0, "gemm", &sharded).latency_us;
    let collective = match gather {
        Some((bytes_in, bytes_out)) => {
            collective_cost(est, slice, CollectiveKind::AllGather, bytes_in, bytes_out)
        }
        None => 0.0,
    };
    GemmSliceReport {
        chips: slice.chips,
        compute_us: compute,
        collective_us: collective,
        single_chip_us: single,
    }
}

/// Cost one collective through the estimator's shape cache: the key
/// carries the device fingerprint and the full slice config, so entries
/// for different devices, different slices, or the single-chip path can
/// never alias.
fn collective_cost(
    est: &Estimator,
    slice: &SliceConfig,
    kind: CollectiveKind,
    bytes_in: u64,
    bytes_out: u64,
) -> f64 {
    if slice.chips <= 1 {
        return 0.0;
    }
    let key = ShapeKey::collective(est.cache_fingerprint(), kind, bytes_in, bytes_out, slice);
    if let Some(hit) = est.cache.lookup(&key) {
        return hit.latency_us;
    }
    let us = IciModel::new(slice).collective_us(kind, bytes_in, bytes_out);
    est.cache.store(
        key,
        CachedCost {
            source: EstimateSource::Bandwidth,
            cycles: None,
            latency_us: us,
            note: format!("{kind} over {} chips ({})", slice.chips, slice.topology),
        },
    );
    us
}

/// Largest chunk of `dim` split across `chips` (the critical shard).
fn max_shard(dim: usize, chips: usize) -> usize {
    split_dim(dim, chips).first().copied().unwrap_or(dim.max(1))
}

/// Row-shard a tensor in place: split the leading axis across chips.
fn shard_leading_dim(t: &mut crate::frontend::types::TensorType, chips: usize) {
    if let Some(d) = t.dims.first_mut() {
        if *d >= 2 {
            *d = max_shard(*d, chips);
        }
    }
}

/// Shard a classified op for SPMD execution on `chips` chips.
///
/// Returns the per-chip class plus, for model-parallel (N-sharded)
/// systolic ops, the `(bytes_in, bytes_out)` of the implicit all-gather
/// that restores the row-sharded layout. With `chips <= 1` the class is
/// returned unchanged.
fn shard_class(
    class: &OpClass,
    sharding: Option<&ShardingAttr>,
    out_bytes: Option<u64>,
    chips: usize,
) -> (OpClass, Option<(u64, u64)>) {
    if chips <= 1 {
        return (class.clone(), None);
    }
    if sharding.map(ShardingAttr::is_replicated).unwrap_or(false) {
        return (class.clone(), None);
    }
    let model_parallel = sharding.map(ShardingAttr::model_parallel).unwrap_or(false);
    match class {
        OpClass::SystolicGemm { gemm, count } => {
            let split_n = model_parallel || (sharding.is_none() && gemm.n > gemm.m);
            if split_n {
                let sharded = GemmShape::new(gemm.m, gemm.k, max_shard(gemm.n, chips));
                // `out_bytes` (when known) is the full batched output
                // tensor; the bf16 fallback must scale by the batch count
                // itself.
                let bytes_out = out_bytes.unwrap_or(gemm.c_words() * 2 * *count).max(1);
                (
                    OpClass::SystolicGemm { gemm: sharded, count: *count },
                    Some((bytes_out / chips as u64, bytes_out)),
                )
            } else {
                let sharded = GemmShape::new(max_shard(gemm.m, chips), gemm.k, gemm.n);
                (OpClass::SystolicGemm { gemm: sharded, count: *count }, None)
            }
        }
        OpClass::SystolicConv { conv, gemm, count } => {
            // Output pixels (M) are row-parallel across chips.
            let sharded = GemmShape::new(max_shard(gemm.m, chips), gemm.k, gemm.n);
            (
                OpClass::SystolicConv {
                    conv: conv.clone(),
                    gemm: sharded,
                    count: *count,
                },
                None,
            )
        }
        OpClass::Elementwise { kind, out } => {
            let mut out = out.clone();
            shard_leading_dim(&mut out, chips);
            (OpClass::Elementwise { kind: *kind, out }, None)
        }
        OpClass::Reduction { input, out } => {
            let mut input = input.clone();
            shard_leading_dim(&mut input, chips);
            (OpClass::Reduction { input, out: out.clone() }, None)
        }
        OpClass::DataMovement { out, .. } => {
            let mut out = out.clone();
            shard_leading_dim(&mut out, chips);
            let bytes = out.size_bytes();
            (OpClass::DataMovement { bytes, out }, None)
        }
        // Collectives are scheduled on the ICI engine by the caller;
        // free and unmodeled ops replicate.
        other => (other.clone(), None),
    }
}

/// Per-op build record: which scheduler nodes realize the op, and how
/// its busy time splits across the two engines.
struct RowPlan {
    index: usize,
    op_name: String,
    /// Node id of the op's main (compute or collective) segment.
    main: usize,
    /// Node id of the implicit all-gather segment, if any.
    gather: Option<usize>,
    /// (compute, ici) busy-time contribution of the main segment — call
    /// blocks split their callee's busy time across both engines.
    busy: (f64, f64),
    /// DMA busy time attributable to the op (memory-aware walks only).
    dma_us: f64,
    note: String,
}

/// The per-chip timeline over one function, built as scheduler nodes
/// (compute lane + ICI lane) and placed by [`place`].
fn walk_func(
    est: &Estimator,
    module: &ModuleInfo,
    func_name: Option<&str>,
    slice: &SliceConfig,
    depth: usize,
    memory: Option<&MemoryConfig>,
) -> DistributedEstimate {
    let mut result = DistributedEstimate {
        module_name: module.name.clone(),
        slice: *slice,
        total_us: 0.0,
        compute_us: 0.0,
        collective_us: 0.0,
        dma_us: 0.0,
        critical_path_us: 0.0,
        single_chip_us: 0.0,
        ops: Vec::new(),
    };
    let Some(func) = func_name.and_then(|n| module.funcs.iter().find(|f| f.name == n))
    else {
        return result;
    };

    let graph = DepGraph::build(func);
    // Memory-aware walks thread tensor residency through the timeline:
    // each op may grow a DMA-in node (cold operand shards) and a DMA-out
    // node (spills / dirty evictions / escapes) on the DMA lane.
    let mut dma = memory.map(|m| DmaTimeline::new(*m, func, slice.chips));
    let mut nodes: Vec<SchedNode> = Vec::new();
    let mut rows: Vec<RowPlan> = Vec::with_capacity(func.ops.len());
    // For each op, the node whose finish marks its results ready (the
    // gather node when the op pays an implicit all-gather).
    let mut provider: Vec<usize> = Vec::with_capacity(func.ops.len());

    for (i, op) in func.ops.iter().enumerate() {
        let mut preds: Vec<usize> = graph.preds[i].iter().map(|&p| provider[p]).collect();

        // Fetch cold operands over HBM before the op runs (`return`
        // reads nothing on chip; its escape is handled at retire).
        let fetch = match dma.as_mut() {
            Some(d) if op.short_name() != "return" => d.fetch(op, &mut nodes),
            _ => FetchDma::default(),
        };
        for &n in fetch.hit_preds.iter().chain(fetch.node.iter()) {
            push_unique(&mut preds, n);
        }

        // Inline calls (mirrors Estimator::estimate_func): the callee is
        // estimated as its own timeline and enters this one as a single
        // compute block.
        if (op.short_name() == "call" || op.op_name == "func.call") && depth < 4 {
            if let Some(callee) = &op.callee {
                // The callee enters this timeline as an opaque block, so
                // its internal HBM traffic is NOT modeled (the caller
                // already charged the call's operands above; threading
                // `memory` down too would bill the arguments twice) —
                // the same non-goal as the single-chip expansion, see
                // DESIGN.md §memory-model.
                let sub = walk_func(est, module, Some(callee), slice, depth + 1, None);
                let main = nodes.len();
                nodes.push(SchedNode {
                    index: op.index,
                    op_name: format!("call @{callee}"),
                    engine: Some(Engine::Mxu),
                    cost_us: sub.total_us,
                    preds,
                    source: "call",
                    note: String::new(),
                });
                // The callee block may use the physical ICI link
                // internally, so a zero-width barrier keeps the caller's
                // ICI lane busy until the call finishes (no
                // double-booking against the callee's own collectives).
                nodes.push(SchedNode {
                    index: op.index,
                    op_name: format!("call @{callee}.ici"),
                    engine: Some(Engine::Ici),
                    cost_us: 0.0,
                    preds: vec![main],
                    source: "call",
                    note: String::new(),
                });
                let retire = match dma.as_mut() {
                    Some(d) => d.retire(op, main, &mut nodes),
                    None => RetireDma::default(),
                };
                rows.push(RowPlan {
                    index: op.index,
                    op_name: format!("call @{callee}"),
                    main,
                    gather: None,
                    busy: (sub.compute_us, sub.collective_us),
                    dma_us: fetch.dma_us + retire.dma_us,
                    note: format!("inlined {} ops", sub.ops.len()),
                });
                provider.push(main);
                continue;
            }
        }

        let class = classify(op);
        if let OpClass::Collective { kind, bytes_in, out } = &class {
            let dur = collective_cost(est, slice, *kind, *bytes_in, out.size_bytes());
            let main = nodes.len();
            nodes.push(SchedNode {
                index: op.index,
                op_name: op.op_name.clone(),
                engine: Some(Engine::Ici),
                cost_us: dur,
                preds,
                source: "bandwidth",
                note: String::new(),
            });
            let retire = match dma.as_mut() {
                Some(d) => d.retire(op, main, &mut nodes),
                None => RetireDma::default(),
            };
            rows.push(RowPlan {
                index: op.index,
                op_name: op.op_name.clone(),
                main,
                gather: None,
                busy: (0.0, dur),
                dma_us: fetch.dma_us + retire.dma_us,
                note: format!("{kind} {out} over ICI"),
            });
            provider.push(main);
            continue;
        }

        let out_bytes = op.out_type().map(|t| t.size_bytes());
        let (sharded, gather) =
            shard_class(&class, op.sharding.as_ref(), out_bytes, slice.chips);
        let e = est.estimate_op(op.index, &op.op_name, &sharded);
        let main = nodes.len();
        nodes.push(SchedNode {
            index: op.index,
            op_name: op.op_name.clone(),
            engine: Some(Engine::Mxu),
            cost_us: e.latency_us,
            preds,
            source: e.source.tag(),
            note: String::new(),
        });
        match gather {
            Some((bytes_in, bytes_out)) => {
                let coll =
                    collective_cost(est, slice, CollectiveKind::AllGather, bytes_in, bytes_out);
                let gnode = nodes.len();
                nodes.push(SchedNode {
                    index: op.index,
                    op_name: format!("{}.all_gather", op.op_name),
                    engine: Some(Engine::Ici),
                    cost_us: coll,
                    preds: vec![main],
                    source: "bandwidth",
                    note: String::new(),
                });
                let retire = match dma.as_mut() {
                    Some(d) => d.retire(op, gnode, &mut nodes),
                    None => RetireDma::default(),
                };
                rows.push(RowPlan {
                    index: op.index,
                    op_name: op.op_name.clone(),
                    main,
                    gather: Some(gnode),
                    busy: (e.latency_us, 0.0),
                    dma_us: fetch.dma_us + retire.dma_us,
                    note: if coll > 0.0 {
                        format!("{} + all_gather(out)", e.note)
                    } else {
                        e.note
                    },
                });
                provider.push(gnode);
            }
            None => {
                let retire = match dma.as_mut() {
                    Some(d) => d.retire(op, main, &mut nodes),
                    None => RetireDma::default(),
                };
                rows.push(RowPlan {
                    index: op.index,
                    op_name: op.op_name.clone(),
                    main,
                    gather: None,
                    busy: (e.latency_us, 0.0),
                    dma_us: fetch.dma_us + retire.dma_us,
                    note: e.note,
                });
                provider.push(main);
            }
        }
    }

    let placements = place(&nodes);
    result.total_us = placements.iter().fold(0.0f64, |acc, p| acc.max(p.end_us));
    result.critical_path_us = critical_path(&nodes);
    // Busy-time accounting in node order (same accumulation order as the
    // timeline walk it replaced, so existing totals are bit-identical).
    for row in &rows {
        result.compute_us += row.busy.0;
        result.collective_us += row.busy.1;
        result.dma_us += row.dma_us;
        if let Some(g) = row.gather {
            result.collective_us += nodes[g].cost_us;
        }
    }
    for row in rows {
        let gather_us = row.gather.map(|g| nodes[g].cost_us).unwrap_or(0.0);
        let finish = placements[row.gather.unwrap_or(row.main)].end_us;
        result.ops.push(DistOpEstimate {
            index: row.index,
            op_name: row.op_name,
            compute_us: row.busy.0,
            collective_us: row.busy.1 + gather_us,
            dma_us: row.dma_us,
            start_us: placements[row.main].start_us,
            finish_us: finish,
            note: row.note,
        });
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::frontend::parse_module;
    use crate::scalesim::ScaleConfig;

    fn estimator() -> Estimator {
        let mut obs = Vec::new();
        for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
            let g = GemmShape::new(d, d, d);
            obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
        }
        Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
    }

    const MLP: &str = r#"
module @m { func.func @main(%x: tensor<1024x1024xf32>, %w: tensor<1024x1024xf32>) -> tensor<1024x1024xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<1024x1024xf32>, tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
  %1 = stablehlo.add %0, %x : tensor<1024x1024xf32>
  return %1 : tensor<1024x1024xf32>
} }"#;

    #[test]
    fn one_chip_slice_matches_single_chip_bit_for_bit() {
        let est = estimator();
        let module = parse_module(MLP).unwrap();
        let single = est.estimate_module(&module);
        let dist =
            estimate_module_distributed(&est, &module, &SliceConfig::single_chip());
        assert_eq!(dist.total_us.to_bits(), single.total_us.to_bits());
        assert_eq!(dist.collective_us, 0.0);
        assert_eq!(dist.parallel_efficiency(), 1.0);
    }

    #[test]
    fn sharding_speeds_up_and_efficiency_is_sane() {
        let est = estimator();
        let module = parse_module(MLP).unwrap();
        let single = est.estimate_module(&module).total_us;
        let dist = estimate_module_distributed(&est, &module, &SliceConfig::ring(4, 100.0));
        assert!(dist.total_us < single, "{} !< {single}", dist.total_us);
        let e = dist.parallel_efficiency();
        assert!(e > 0.0 && e <= 1.0, "efficiency {e}");
        assert!(dist.speedup() > 1.0);
    }

    #[test]
    fn model_parallel_sharding_pays_an_all_gather() {
        let text = r#"
module @m { func.func @main(%x: tensor<128x1024xf32>, %w: tensor<1024x4096xf32>) -> tensor<128x4096xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] {mhlo.sharding = "{devices=[1,4]<=[4]}"} : (tensor<128x1024xf32>, tensor<1024x4096xf32>) -> tensor<128x4096xf32>
  return %0 : tensor<128x4096xf32>
} }"#;
        let est = estimator();
        let module = parse_module(text).unwrap();
        let dist = estimate_module_distributed(&est, &module, &SliceConfig::ring(4, 100.0));
        assert!(dist.collective_us > 0.0, "implicit all-gather missing");
        assert!(dist.ops[0].note.contains("all_gather"));
    }

    #[test]
    fn explicit_collectives_ride_the_ici_engine_and_overlap() {
        let text = r#"
module @m { func.func @main(%x: tensor<1024x1024xf32>, %w: tensor<1024x1024xf32>) -> tensor<1024x1024xf32> {
  %0 = "stablehlo.all_reduce"(%x) ({
  ^bb0(%a: tensor<f32>, %b: tensor<f32>):
    %s = stablehlo.add %a, %b : tensor<f32>
    stablehlo.return %s : tensor<f32>
  }) {replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>} : (tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
  %1 = stablehlo.dot_general %w, %w, contracting_dims = [1] x [0] : (tensor<1024x1024xf32>, tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
  %2 = stablehlo.add %0, %1 : tensor<1024x1024xf32>
  return %2 : tensor<1024x1024xf32>
} }"#;
        let est = estimator();
        let module = parse_module(text).unwrap();
        let slice = SliceConfig::ring(4, 25.0);
        let dist = estimate_module_distributed(&est, &module, &slice);
        assert!(dist.collective_us > 0.0);
        // The all_reduce does not feed the dot: the timeline overlaps
        // them, so the makespan is below the serial sum of busy times.
        assert!(
            dist.total_us < dist.compute_us + dist.collective_us,
            "no overlap: makespan {} vs busy {} + {}",
            dist.total_us,
            dist.compute_us,
            dist.collective_us
        );
        assert!(dist.overlapped_us() > 0.0);
    }

    #[test]
    fn latency_monotone_in_link_bandwidth() {
        let text = r#"
module @m { func.func @main(%x: tensor<128x1024xf32>, %w: tensor<1024x4096xf32>) -> tensor<128x4096xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] {mhlo.sharding = "{devices=[1,8]<=[8]}"} : (tensor<128x1024xf32>, tensor<1024x4096xf32>) -> tensor<128x4096xf32>
  return %0 : tensor<128x4096xf32>
} }"#;
        let est = estimator();
        let module = parse_module(text).unwrap();
        let mut last = f64::INFINITY;
        for gbps in [5.0, 20.0, 80.0, 320.0] {
            let d = estimate_module_distributed(&est, &module, &SliceConfig::ring(8, gbps));
            assert!(d.total_us < last, "not monotone at {gbps} GB/s");
            last = d.total_us;
        }
    }

    #[test]
    fn critical_path_bounds_the_makespan() {
        let est = estimator();
        let module = parse_module(MLP).unwrap();
        for chips in [1usize, 4, 8] {
            let d = estimate_module_distributed(&est, &module, &SliceConfig::ring(chips, 50.0));
            assert!(
                d.critical_path_us <= d.total_us,
                "critical path {} > makespan {} at {chips} chips",
                d.critical_path_us,
                d.total_us
            );
            assert!(d.critical_path_us > 0.0);
            // Ops report timeline placement: start before finish.
            for op in &d.ops {
                assert!(op.start_us <= op.finish_us, "{op:?}");
            }
        }
    }

    #[test]
    fn gemm_slice_report_roundtrip() {
        let est = estimator();
        let g = GemmShape::new(4096, 1024, 1024);
        let one = estimate_gemm_sliced(&est, g, &SliceConfig::single_chip());
        let single = est
            .estimate_op(0, "gemm", &OpClass::SystolicGemm { gemm: g, count: 1 })
            .latency_us;
        assert_eq!(one.total_us().to_bits(), single.to_bits());
        let four = estimate_gemm_sliced(&est, g, &SliceConfig::ring(4, 100.0));
        assert!(four.total_us() < single);
        let e = four.parallel_efficiency();
        assert!(e > 0.0 && e <= 1.0);
    }
}
