//! Integration: the PJRT runtime executing real AOT artifacts, with
//! cross-language numeric checks (Rust-computed oracles vs the
//! JAX/Pallas-lowered executables).

use scalesim_tpu::runtime::{f32_literal, hlo_gen, Runtime};

/// Obtain a PJRT runtime or skip: offline builds (no `pjrt` feature)
/// stub the client out and every construction fails cleanly.
macro_rules! runtime_or_skip {
    () => {
        match Runtime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT runtime unavailable ({e:#})");
                return;
            }
        }
    };
}

#[test]
fn synthesised_gemm_matches_rust_oracle() {
    let rt = runtime_or_skip!();
    let (m, k, n) = (17, 23, 11);
    let exe = rt
        .compile_text("gemm", &hlo_gen::gemm_hlo(m, k, n))
        .unwrap();

    // Deterministic inputs.
    let a_data: Vec<f32> = (0..m * k).map(|i| ((i % 7) as f32) * 0.5 - 1.0).collect();
    let b_data: Vec<f32> = (0..k * n).map(|i| ((i % 5) as f32) * 0.25).collect();
    let a = f32_literal(&[m, k], |i| a_data[i]).unwrap();
    let b = f32_literal(&[k, n], |i| b_data[i]).unwrap();
    let out = exe.run_f32(&[a, b]).unwrap();

    // Naive Rust matmul oracle.
    let mut expect = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += a_data[i * k + kk] * b_data[kk * n + j];
            }
            expect[i * n + j] = acc;
        }
    }
    assert_eq!(out.len(), expect.len());
    for (o, e) in out.iter().zip(&expect) {
        assert!((o - e).abs() < 1e-3, "{o} vs {e}");
    }
}

#[test]
fn aot_gemm_artifact_matches_rust_oracle() {
    // The Pallas-lowered artifact must compute the same matmul as a naive
    // Rust triple loop — the strongest cross-layer correctness check.
    let path = std::path::Path::new("artifacts/gemm_m128_k256_n512.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = runtime_or_skip!();
    let exe = rt.compile_file(path).expect("compile artifact");

    let (m, k, n) = (128usize, 256usize, 512usize);
    let a_data: Vec<f32> = (0..m * k).map(|i| ((i % 13) as f32) * 0.1 - 0.6).collect();
    let b_data: Vec<f32> = (0..k * n).map(|i| ((i % 11) as f32) * 0.2 - 1.0).collect();
    let a = f32_literal(&[m, k], |i| a_data[i]).unwrap();
    let b = f32_literal(&[k, n], |i| b_data[i]).unwrap();
    let out = exe.run_f32(&[a, b]).expect("execute artifact");
    assert_eq!(out.len(), m * n);

    // Spot-check a grid of output elements against the oracle.
    for &(i, j) in &[(0, 0), (0, 511), (127, 0), (127, 511), (64, 256), (13, 87)] {
        let mut acc = 0f32;
        for kk in 0..k {
            acc += a_data[i * k + kk] * b_data[kk * n + j];
        }
        let got = out[i * n + j];
        assert!(
            (got - acc).abs() < 1e-2 * acc.abs().max(1.0),
            "C[{i},{j}] = {got}, expected {acc}"
        );
    }
}

#[test]
fn aot_relu_artifact_behaviour() {
    let path = std::path::Path::new("artifacts/ew_relu_1024x1024.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = runtime_or_skip!();
    let exe = rt.compile_file(path).expect("compile relu artifact");
    let x = f32_literal(&[1024, 1024], |i| (i as f32 % 9.0) - 4.0).unwrap();
    let out = exe.run_f32(&[x]).expect("execute relu");
    assert_eq!(out.len(), 1024 * 1024);
    assert!(out.iter().all(|&v| v >= 0.0));
    // max(x, 0) of the pattern (-4..=4) keeps positives intact.
    assert_eq!(out[5], 1.0); // (5 % 9) - 4 = 1
    assert_eq!(out[0], 0.0); // -4 clamps
}

#[test]
fn mlp_artifact_executes_finite() {
    let path = std::path::Path::new("artifacts/mlp_b32.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let rt = runtime_or_skip!();
    let exe = rt.compile_file(path).expect("compile mlp artifact");
    let x = f32_literal(&[32, 784], |i| ((i % 255) as f32) / 255.0).unwrap();
    let out = exe.run_f32(&[x]).expect("execute mlp");
    assert_eq!(out.len(), 32 * 10);
    assert!(out.iter().all(|v| v.is_finite()));
    // Logits should not be identical across classes (weights are random
    // but fixed at AOT time).
    let first_row = &out[..10];
    assert!(first_row.iter().any(|&v| (v - first_row[0]).abs() > 1e-6));
}

#[test]
fn timing_is_reproducible_order_of_magnitude() {
    let rt = runtime_or_skip!();
    let exe = rt
        .compile_text("add", &hlo_gen::binary_ew_hlo("add", &[512, 512]))
        .unwrap();
    let a = f32_literal(&[512, 512], |i| i as f32).unwrap();
    let b = f32_literal(&[512, 512], |i| i as f32).unwrap();
    let t1 = exe.time_us(&[a.clone(), b.clone()], 3, 9).unwrap();
    let t2 = exe.time_us(&[a, b], 0, 9).unwrap();
    let m1 = scalesim_tpu::util::stats::median(&t1);
    let m2 = scalesim_tpu::util::stats::median(&t2);
    assert!(m1 > 0.0 && m2 > 0.0);
    assert!(m1 / m2 < 20.0 && m2 / m1 < 20.0, "{m1} vs {m2}");
}
