//! Property tests: the batched estimator core is bit-identical to the
//! scalar per-op walk — every device preset × every `.mlir` fixture,
//! cache-cold and cache-warm, row fields, totals and hit/miss counters
//! all compared exactly (the tentpole invariant of
//! `coordinator::batch`).

use scalesim_tpu::coordinator::{Estimator, ModelEstimate};
use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::experiments::assets;
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::sweep::sweep_estimator;
use scalesim_tpu::tpu::TpuV4Model;

const FIXTURES: [(&str, &str); 4] = [
    ("bert_layer", include_str!("fixtures/bert_layer.mlir")),
    ("collectives", include_str!("fixtures/collectives.mlir")),
    ("sharded_mlp", include_str!("fixtures/sharded_mlp.mlir")),
    ("while_loop", include_str!("fixtures/while_loop.stablehlo.txt")),
];

fn fixtures() -> Vec<(&'static str, ModuleInfo)> {
    FIXTURES
        .iter()
        .map(|(name, text)| (*name, parse_module(text).expect(name)))
        .collect()
}

/// Every field of every row, plus the totals, compared bit-exactly.
fn assert_identical(a: &ModelEstimate, b: &ModelEstimate, ctx: &str) {
    assert_eq!(a.module_name, b.module_name, "{ctx}: module name");
    assert_eq!(a.ops.len(), b.ops.len(), "{ctx}: row count");
    for (x, y) in a.ops.iter().zip(&b.ops) {
        assert_eq!(x.index, y.index, "{ctx}: row index");
        assert_eq!(x.op_name, y.op_name, "{ctx}: op name at {}", x.index);
        assert_eq!(x.source, y.source, "{ctx}: source for {}", x.op_name);
        assert_eq!(x.cycles, y.cycles, "{ctx}: cycles for {}", x.op_name);
        assert_eq!(
            x.latency_us.to_bits(),
            y.latency_us.to_bits(),
            "{ctx}: latency for {} ({} vs {})",
            x.op_name,
            x.latency_us,
            y.latency_us
        );
        assert_eq!(x.note, y.note, "{ctx}: note for {}", x.op_name);
    }
    assert_eq!(
        a.total_us.to_bits(),
        b.total_us.to_bits(),
        "{ctx}: total ({} vs {})",
        a.total_us,
        b.total_us
    );
    assert_eq!(a.systolic_us.to_bits(), b.systolic_us.to_bits(), "{ctx}: systolic");
    assert_eq!(
        a.elementwise_us.to_bits(),
        b.elementwise_us.to_bits(),
        "{ctx}: elementwise"
    );
    assert_eq!(a.other_us.to_bits(), b.other_us.to_bits(), "{ctx}: other");
    assert_eq!(a.covered_ops, b.covered_ops, "{ctx}: covered ops");
    assert_eq!(a.total_costed_ops, b.total_costed_ops, "{ctx}: costed ops");
}

fn counters(est: &Estimator) -> (u64, u64) {
    let s = est.cache.stats();
    (s.hits, s.misses)
}

/// The tentpole property: for every preset and fixture, the batched
/// `estimate_module` and the scalar reference walk agree bit for bit —
/// cold (first touch) and warm (cache primed) — and their hit/miss
/// counters match exactly at both points.
#[test]
fn batched_matches_scalar_on_every_preset_and_fixture() {
    for spec in DeviceSpec::presets() {
        for (name, module) in &fixtures() {
            let scalar_est = sweep_estimator(&spec);
            let batched_est = sweep_estimator(&spec);

            let cold_scalar = scalar_est.estimate_module_scalar(module);
            let cold_batched = batched_est.estimate_module(module);
            assert_identical(
                &cold_scalar,
                &cold_batched,
                &format!("{}/{name} cold", spec.name),
            );
            assert_eq!(
                counters(&scalar_est),
                counters(&batched_est),
                "{}/{name}: cold hit/miss counters",
                spec.name
            );

            let warm_scalar = scalar_est.estimate_module_scalar(module);
            let warm_batched = batched_est.estimate_module(module);
            assert_identical(
                &warm_scalar,
                &warm_batched,
                &format!("{}/{name} warm", spec.name),
            );
            assert_identical(
                &cold_batched,
                &warm_batched,
                &format!("{}/{name} cold-vs-warm", spec.name),
            );
            assert_eq!(
                counters(&scalar_est),
                counters(&batched_est),
                "{}/{name}: warm hit/miss counters",
                spec.name
            );
        }
    }
}

/// With memoisation disabled the batched core must still reproduce the
/// scalar walk exactly (no cache to launder differences through).
#[test]
fn batched_matches_scalar_with_cache_disabled() {
    for spec in DeviceSpec::presets() {
        for (name, module) in &fixtures() {
            let scalar_est = sweep_estimator(&spec);
            let batched_est = sweep_estimator(&spec);
            scalar_est.cache.set_enabled(false);
            batched_est.cache.set_enabled(false);
            for round in 0..2 {
                let a = scalar_est.estimate_module_scalar(module);
                let b = batched_est.estimate_module(module);
                assert_identical(&a, &b, &format!("{}/{name} uncached r{round}", spec.name));
            }
            assert_eq!(
                counters(&batched_est),
                (0, 0),
                "{}/{name}: disabled cache must count nothing",
                spec.name
            );
        }
    }
}

/// Lower once, estimate many times: the pre-lowered table path must
/// match fresh per-call lowering on a second estimator, counters
/// included.
#[test]
fn pre_lowered_table_reuse_is_bit_identical() {
    let spec = DeviceSpec::tpu_v4();
    for (name, module) in &fixtures() {
        let table_est = sweep_estimator(&spec);
        let fresh_est = sweep_estimator(&spec);
        let table = table_est.lower_module(module);
        for round in 0..3 {
            let a = table_est.estimate_table(&table);
            let b = fresh_est.estimate_module(module);
            assert_identical(&a, &b, &format!("{name} table r{round}"));
        }
        assert_eq!(
            counters(&table_est),
            counters(&fresh_est),
            "{name}: table-reuse counters"
        );
    }
}

/// The learned-model batch path (grouped featurize + compiled HGBR
/// `predict_many`) agrees with the scalar walk: two estimators built
/// from identically-seeded synthetic hardware, scalar vs batched, cold
/// and warm. Exercises the Learned/LearnedProxy arms the synthetic
/// sweep estimator (no learned models) cannot reach.
#[test]
fn batched_matches_scalar_with_learned_models() {
    let spec = DeviceSpec::tpu_v4();
    let build = || {
        let mut hw = TpuV4Model::for_device(&spec, 11);
        assets::build_estimator(&mut hw, &spec, 40, 1, 11)
    };
    let scalar_est = build();
    let batched_est = build();
    for (name, module) in &fixtures() {
        for round in 0..2 {
            let a = scalar_est.estimate_module_scalar(module);
            let b = batched_est.estimate_module(module);
            assert_identical(&a, &b, &format!("learned/{name} r{round}"));
        }
    }
    assert_eq!(
        counters(&scalar_est),
        counters(&batched_est),
        "learned-path hit/miss counters"
    );
    // The fixtures contain add/multiply ops, so the learned arm really ran.
    let report = batched_est.estimate_module(&fixtures()[0].1);
    assert!(
        report
            .ops
            .iter()
            .any(|o| o.source == scalesim_tpu::coordinator::EstimateSource::Learned),
        "expected at least one learned-model estimate in bert_layer"
    );
}
