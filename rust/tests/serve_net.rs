//! Concurrency battery for the TCP service: in-order per-connection
//! streaming under 16-way client concurrency, bit-identity against the
//! single-threaded batch path, graceful drain accounting, and the
//! warm-cache snapshot round trip.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;

use scalesim_tpu::coordinator::{
    default_workers, load_snapshot, save_snapshot, serve_lines, Estimator, NetOptions, NetServer,
    NetSummary, ShutdownHandle,
};
use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::sweep::sweep_estimator;
use scalesim_tpu::util::json::Json;

/// A server over a deterministic sweep-calibrated tpu-v4 estimator.
fn spawn_server(
    opts: NetOptions,
) -> (
    SocketAddr,
    ShutdownHandle,
    JoinHandle<NetSummary>,
    Arc<Estimator>,
) {
    let est = Arc::new(sweep_estimator(&DeviceSpec::tpu_v4()));
    let server = NetServer::bind("127.0.0.1:0", Arc::clone(&est), opts).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = server.shutdown_handle();
    let join = std::thread::spawn(move || server.run().unwrap());
    (addr, handle, join, est)
}

/// Send `lines` on one connection (half-closing the write side to mark
/// the end) and collect every response line until the server closes.
fn run_conn(addr: SocketAddr, lines: &[String]) -> Vec<String> {
    let mut conn = TcpStream::connect(addr).unwrap();
    for line in lines {
        writeln!(conn, "{line}").unwrap();
    }
    conn.flush().unwrap();
    conn.shutdown(Shutdown::Write).unwrap();
    BufReader::new(conn).lines().map(|l| l.unwrap()).collect()
}

const CLIENTS: usize = 16;
const REQUESTS: usize = 500;

/// Client c's request stream: cycles a *shared* pool of 24 shapes with a
/// per-client phase, so concurrent connections contend on the same cache
/// entries in interleavings that differ run to run.
fn client_lines(c: usize) -> Vec<String> {
    (0..REQUESTS)
        .map(|i| {
            let d = 32 + 16 * ((i + c) % 24);
            format!(r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#)
        })
        .collect()
}

#[test]
fn sixteen_concurrent_clients_in_order_and_bit_identical_to_batch() {
    let (addr, handle, join, _est) = spawn_server(NetOptions::default());

    // 16 concurrent connections x 500 requests. Each client writes from
    // a helper thread and reads on its own, so server-side backpressure
    // (the per-connection in-flight gate) can never deadlock a client.
    let clients: Vec<JoinHandle<Vec<String>>> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let lines = client_lines(c);
                let conn = TcpStream::connect(addr).unwrap();
                let mut wr = conn.try_clone().unwrap();
                let writer = std::thread::spawn(move || {
                    for line in &lines {
                        writeln!(wr, "{line}").unwrap();
                    }
                    wr.flush().unwrap();
                });
                let mut reader = BufReader::new(conn);
                let mut responses = Vec::with_capacity(REQUESTS);
                let mut buf = String::new();
                for _ in 0..REQUESTS {
                    buf.clear();
                    assert!(reader.read_line(&mut buf).unwrap() > 0, "server closed early");
                    responses.push(buf.trim_end().to_string());
                }
                writer.join().unwrap();
                responses
            })
        })
        .collect();
    let per_client: Vec<Vec<String>> = clients.into_iter().map(|c| c.join().unwrap()).collect();

    // Every connection's responses arrive in its own request order, and
    // each is bit-identical to the same requests run through the
    // single-threaded batch path on a fresh estimator — shared-cache
    // results must not depend on interleaving.
    for (c, responses) in per_client.iter().enumerate() {
        for (i, resp) in responses.iter().enumerate() {
            let j = Json::parse(resp).expect("response is JSON");
            assert_eq!(j.req_f64("id").unwrap(), i as f64, "client {c} out of order: {resp}");
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{resp}");
        }
        let baseline = serve_lines(
            Arc::new(sweep_estimator(&DeviceSpec::tpu_v4())),
            &client_lines(c),
            1,
        );
        assert_eq!(responses, &baseline, "client {c} diverged from the batch path");
    }

    handle.shutdown();
    let summary = join.join().unwrap();
    assert_eq!(summary.connections, CLIENTS as u64);
    assert_eq!(summary.stream.requests, (CLIENTS * REQUESTS) as u64);
    assert_eq!(summary.stream.ok, (CLIENTS * REQUESTS) as u64);
    assert_eq!(summary.stream.errors, 0);
    assert_eq!(summary.stream.gemm, (CLIENTS * REQUESTS) as u64);
    // 24 distinct shapes on one device; everything else hit the cache
    // (racing workers may both miss a fresh key, so misses are bounded,
    // not exact).
    let cache = summary.stream.cache;
    assert_eq!(cache.hits + cache.misses, (CLIENTS * REQUESTS) as u64);
    assert_eq!(cache.entries, 24);
    // Concurrent workers may each miss a fresh key once before the first
    // store lands, so misses are bounded by keys x workers, not exact.
    let miss_bound = (24 * default_workers().max(1)) as u64;
    assert!(cache.misses <= miss_bound, "misses {} > {miss_bound}", cache.misses);
}

#[test]
fn drain_answers_every_inflight_request_exactly_once() {
    let (addr, _handle, join, _est) = spawn_server(NetOptions {
        workers: 4,
        ..NetOptions::default()
    });

    // 100 requests and the shutdown admin request land in one write, so
    // the drain triggers while the pool is still answering the backlog.
    let mut payload = String::new();
    for i in 0..100 {
        let d = 32 + 16 * (i % 10);
        payload.push_str(&format!("{{\"type\":\"gemm\",\"m\":{d},\"k\":{d},\"n\":{d}}}\n"));
    }
    payload.push_str("{\"type\":\"shutdown\"}\n");
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(payload.as_bytes()).unwrap();
    conn.flush().unwrap();
    let lines: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();

    // Every accepted request is answered, in order, exactly once — the
    // gemm backlog first, the shutdown acknowledgement last.
    assert_eq!(lines.len(), 101);
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).expect("response is JSON");
        assert_eq!(j.req_f64("id").unwrap(), i as f64, "out of order: {line}");
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
    }
    assert_eq!(Json::parse(&lines[100]).unwrap().req_str("type").unwrap(), "shutdown");

    // The final summary counts every request exactly once.
    let summary = join.join().unwrap();
    assert_eq!(summary.connections, 1);
    assert_eq!(summary.stream.requests, 101);
    assert_eq!(summary.stream.ok, 101);
    assert_eq!(summary.stream.errors, 0);
    assert_eq!(summary.stream.gemm, 100);

    // And the listener is gone: new connections are refused.
    assert!(
        TcpStream::connect(addr).is_err(),
        "drained server must refuse new connections"
    );
}

/// Warm-up traffic shared by the snapshot test's phases.
fn warm_lines() -> Vec<String> {
    let mut lines = Vec::new();
    for d in [64usize, 96, 128, 160, 64, 96] {
        lines.push(format!(r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#));
    }
    lines.push(r#"{"type":"elementwise","op":"add","dims":[256,256]}"#.into());
    lines.push(r#"{"type":"elementwise","op":"tanh","dims":[128,128]}"#.into());
    lines
}

/// Probe traffic: mostly warm shapes, one cold, and a stats request
/// whose counters must match between a continuously-warm server and a
/// snapshot-restarted one.
fn probe_lines() -> Vec<String> {
    let mut lines = Vec::new();
    for d in [64usize, 128, 160, 192, 96] {
        lines.push(format!(r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#));
    }
    lines.push(r#"{"type":"elementwise","op":"add","dims":[256,256]}"#.into());
    lines.push(r#"{"type":"stats"}"#.into());
    lines
}

#[test]
fn snapshot_restart_is_bit_identical_to_continuously_warm_server() {
    // Single worker: hit/miss counts are deterministic (no two workers
    // racing the same fresh key), so the stats responses and summaries
    // must match to the bit across the restart.
    let one_worker = || NetOptions {
        workers: 1,
        ..NetOptions::default()
    };
    let dir = std::env::temp_dir().join("scalesim_serve_net_snapshot");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cache.snapshot.jsonl");
    std::fs::remove_file(&path).ok();

    // Phase A: continuously warm — warm + probe on one server lifetime.
    let (addr, handle, join, _est) = spawn_server(one_worker());
    let _ = run_conn(addr, &warm_lines());
    let baseline_probe = run_conn(addr, &probe_lines());
    handle.shutdown();
    let baseline_summary = join.join().unwrap();

    // Phase B: warm, drain, snapshot...
    let (addr, handle, join, est) = spawn_server(one_worker());
    let warm_responses = run_conn(addr, &warm_lines());
    assert_eq!(warm_responses.len(), warm_lines().len());
    handle.shutdown();
    join.join().unwrap();
    save_snapshot(&path, &est).unwrap();

    // ...restart cold, reload, probe.
    let (addr, handle, join, est2) = spawn_server(one_worker());
    assert!(est2.cache.is_empty());
    let loaded = load_snapshot(&path, &est2).unwrap();
    assert_eq!(loaded, est2.cache.len() as u64);
    let restart_probe = run_conn(addr, &probe_lines());
    handle.shutdown();
    let restart_summary = join.join().unwrap();

    // Warm-start responses — including the stats line's hit/miss/source
    // counters — are bit-identical to the continuously-warm server.
    assert_eq!(restart_probe, baseline_probe);
    assert_eq!(restart_summary.stream.cache, baseline_summary.stream.cache);
    assert_eq!(restart_summary.stream.requests, probe_lines().len() as u64);
}
