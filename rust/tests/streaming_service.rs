//! Integration: the streaming estimation service and its sharded shape
//! cache — the acceptance path of the "serve" subcommand.
//!
//! Covers: ≥10k mixed JSONL requests answered incrementally and in
//! order; hit/miss accounting; cross-thread consistency under
//! `parallel_map`; and bit-identical cached vs uncached outputs.

use std::sync::Arc;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::{
    parallel_map, serve_stream, Estimator, ShapeClass, StreamOptions,
};
use scalesim_tpu::frontend::classify::OpClass;
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};
use scalesim_tpu::util::json::Json;

fn estimator() -> Arc<Estimator> {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Arc::new(Estimator::new(
        ScaleConfig::tpu_v4(),
        fit_regime_calibration(&obs).unwrap(),
    ))
}

/// A mixed request stream: gemms over a small shape vocabulary (heavy
/// repetition, as compiler traffic looks), elementwise ops, and a few
/// malformed lines.
fn mixed_stream(n: usize) -> String {
    let mut s = String::new();
    for i in 0..n {
        match i % 10 {
            9 => s.push_str("{\"type\":\"nope\"}\n"),
            7 | 8 => {
                let d = 128 << (i % 3); // 128/256/512 square elementwise
                s.push_str(&format!(
                    "{{\"type\":\"elementwise\",\"op\":\"add\",\"dims\":[{d},{d}]}}\n"
                ));
            }
            r => {
                let d = 64 * (1 + (r % 5)); // 5 distinct gemm shapes
                s.push_str(&format!("{{\"type\":\"gemm\",\"m\":{d},\"k\":{d},\"n\":{d}}}\n"));
            }
        }
    }
    s
}

#[test]
fn ten_thousand_mixed_requests_stream_in_order() {
    const N: usize = 10_000;
    let input = mixed_stream(N);
    let mut out = Vec::new();
    let summary = serve_stream(
        estimator(),
        input.as_bytes(),
        &mut out,
        &StreamOptions {
            workers: 8,
            queue_cap: 32,
        },
    )
    .expect("stream serves");

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), N, "one response per request");
    let mut ok_count = 0u64;
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).expect("valid JSON response");
        assert_eq!(
            j.req_f64("id").unwrap(),
            i as f64,
            "response {i} out of order: {line}"
        );
        if j.get("ok") == Some(&Json::Bool(true)) {
            ok_count += 1;
            assert!(j.req_f64("latency_us").unwrap_or(f64::MAX) >= 0.0);
        }
    }
    assert_eq!(summary.requests, N as u64);
    assert_eq!(summary.ok, ok_count);
    assert_eq!(summary.errors, N as u64 / 10);
    // Only 5 gemm + 3 elementwise shapes exist: the cache must have
    // absorbed nearly all of the 9000 costed requests.
    assert_eq!(summary.cache.entries, 8);
    assert!(
        summary.cache.hits > 8_800,
        "expected heavy hit traffic, got {:?}",
        summary.cache
    );
    assert!(summary.cache.systolic >= 7_000);
    assert!(summary.cache.fallback >= 2_000); // no learned models loaded
}

#[test]
fn cached_and_uncached_streams_are_bit_identical() {
    let input = mixed_stream(600);

    let cached_est = estimator();
    let mut cached_out = Vec::new();
    serve_stream(
        Arc::clone(&cached_est),
        input.as_bytes(),
        &mut cached_out,
        &StreamOptions::default(),
    )
    .unwrap();

    let uncached_est = estimator();
    uncached_est.cache.set_enabled(false);
    let mut uncached_out = Vec::new();
    serve_stream(
        Arc::clone(&uncached_est),
        input.as_bytes(),
        &mut uncached_out,
        &StreamOptions::default(),
    )
    .unwrap();

    assert!(cached_est.cache.stats().hits > 0, "cache saw traffic");
    assert_eq!(uncached_est.cache.stats().hits, 0, "baseline bypassed");
    // Byte-for-byte identical responses, including every f64 digit.
    assert_eq!(
        String::from_utf8(cached_out).unwrap(),
        String::from_utf8(uncached_out).unwrap()
    );
}

#[test]
fn cache_is_consistent_across_parallel_map_workers() {
    let est = estimator();
    let shapes: Vec<GemmShape> = (0..512)
        .map(|i| {
            let d = 128 * (1 + (i % 4));
            GemmShape::new(d, d, d)
        })
        .collect();

    let latencies = parallel_map(&shapes, 8, |g| {
        let class = OpClass::SystolicGemm { gemm: *g, count: 1 };
        est.estimate_op(0, "dot", &class).latency_us
    });

    // Every occurrence of a shape got the exact same answer.
    for (g, us) in shapes.iter().zip(&latencies) {
        let class = OpClass::SystolicGemm { gemm: *g, count: 1 };
        let again = est.estimate_op(0, "dot", &class).latency_us;
        assert_eq!(us.to_bits(), again.to_bits(), "{g} diverged");
    }

    let s = est.cache.stats();
    // 512 parallel lookups + 512 verification lookups, all accounted for.
    assert_eq!(s.hits + s.misses, 1024);
    assert_eq!(s.entries, 4);
    // Racing workers may both miss a fresh key, but never more than once
    // per worker per key.
    assert!((4u64..=32).contains(&s.misses), "misses {}", s.misses);
}

#[test]
fn repeated_shapes_estimate_faster_through_the_cache() {
    // A coarse guard (the precise numbers live in `cargo bench cache`):
    // re-estimating a repeated shape through the cache must beat
    // cycle-accurate re-simulation by a clear margin.
    let est = estimator();
    let shapes: Vec<GemmShape> = (0..8)
        .map(|i| GemmShape::new(1024 + 128 * i, 2048, 1024))
        .collect();
    let classes: Vec<OpClass> = shapes
        .iter()
        .map(|g| OpClass::SystolicGemm { gemm: *g, count: 1 })
        .collect();
    const ROUNDS: usize = 200;

    est.cache.set_enabled(false);
    let t0 = std::time::Instant::now();
    for _ in 0..ROUNDS {
        for c in &classes {
            std::hint::black_box(est.estimate_op(0, "dot", c));
        }
    }
    let uncached = t0.elapsed();

    est.cache.set_enabled(true);
    for c in &classes {
        std::hint::black_box(est.estimate_op(0, "dot", c)); // prime
    }
    let t1 = std::time::Instant::now();
    for _ in 0..ROUNDS {
        for c in &classes {
            std::hint::black_box(est.estimate_op(0, "dot", c));
        }
    }
    let cached = t1.elapsed();

    assert!(
        uncached.as_secs_f64() > cached.as_secs_f64() * 1.5,
        "cache gave no speedup: uncached {uncached:?} vs cached {cached:?}"
    );
}

#[test]
fn shape_key_distinguishes_conv_count_but_shares_gemm() {
    // dot_general and an im2col-lowered convolution with the same GEMM
    // share one entry; a different batch count is a different key.
    let k1 = ShapeClass::Gemm {
        gemm: GemmShape::new(196, 27, 64),
        count: 1,
    };
    let k2 = ShapeClass::Gemm {
        gemm: GemmShape::new(196, 27, 64),
        count: 4,
    };
    assert_ne!(k1, k2);
    assert_eq!(
        k1,
        ShapeClass::Gemm {
            gemm: GemmShape::new(196, 27, 64),
            count: 1
        }
    );
}
