//! Memory-model invariants over random DAGs and every checked-in
//! `.mlir` fixture.
//!
//! The load-bearing properties (all exact, no epsilon — they follow
//! from the monotonicity of `max`/`+` on non-negative floats):
//!
//! * compute-only makespan `<=` memory-aware makespan `<=` the
//!   serialized bound (every compute op and cold transfer back to
//!   back);
//! * the infinite config (unbounded buffer + infinite bandwidth) is
//!   **bit-identical** to the compute-only schedule — single-chip and
//!   across a distributed slice;
//! * a zero-byte buffer never hits; no buffer out-hits the unbounded
//!   one; cold traffic never drops below the unbounded buffer's
//!   first-touch traffic;
//! * with uniform tensor sizes (LRU inclusion holds), hits are
//!   monotone non-decreasing in buffer size.

use std::path::Path;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::Estimator;
use scalesim_tpu::distributed::{
    estimate_module_distributed, estimate_module_distributed_memory, SliceConfig,
};
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::graph::{schedule_estimate, EngineConfig};
use scalesim_tpu::memory::{schedule_estimate_memory, MemoryConfig, MemorySchedule};
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};
use scalesim_tpu::util::prng::Prng;

fn estimator() -> Estimator {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
}

/// A random type-consistent DAG over square `DxD` f32 tensors (uniform
/// footprints, so the LRU inclusion property applies), mixing MXU
/// (dot), VPU (add/multiply/maximum/tanh) and DMA (transpose) work.
fn random_dag_module(prng: &mut Prng, d: usize) -> String {
    let n_ops = 4 + prng.index(12);
    let mut vals: Vec<String> = vec!["a".into(), "b".into()];
    let mut body = String::new();
    for i in 0..n_ops {
        let x = vals[prng.index(vals.len())].clone();
        let y = vals[prng.index(vals.len())].clone();
        let line = match prng.index(6) {
            0 => format!(
                "    %v{i} = stablehlo.dot_general %{x}, %{y}, contracting_dims = [1] x [0] : (tensor<{d}x{d}xf32>, tensor<{d}x{d}xf32>) -> tensor<{d}x{d}xf32>\n"
            ),
            1 => format!("    %v{i} = stablehlo.add %{x}, %{y} : tensor<{d}x{d}xf32>\n"),
            2 => format!("    %v{i} = stablehlo.multiply %{x}, %{y} : tensor<{d}x{d}xf32>\n"),
            3 => format!("    %v{i} = stablehlo.maximum %{x}, %{y} : tensor<{d}x{d}xf32>\n"),
            4 => format!("    %v{i} = stablehlo.tanh %{x} : tensor<{d}x{d}xf32>\n"),
            _ => format!(
                "    %v{i} = stablehlo.transpose %{x}, dims = [1, 0] : (tensor<{d}x{d}xf32>) -> tensor<{d}x{d}xf32>\n"
            ),
        };
        body.push_str(&line);
        vals.push(format!("v{i}"));
    }
    let last = vals.last().unwrap();
    format!(
        "module @rand_mem {{\n  func.func @main(%a: tensor<{d}x{d}xf32>, %b: tensor<{d}x{d}xf32>) -> tensor<{d}x{d}xf32> {{\n{body}    return %{last} : tensor<{d}x{d}xf32>\n  }}\n}}"
    )
}

/// Structural sanity of the per-op memory rows.
fn check_rows(mem: &MemorySchedule, label: &str) {
    let mut hits = 0usize;
    let mut cold = 0usize;
    let mut cold_bytes = 0u64;
    let mut writeback_bytes = 0u64;
    for op in &mem.ops {
        assert!(op.dma_in_us >= 0.0 && op.dma_out_us >= 0.0, "{label} {op:?}");
        assert!(op.start_us <= op.end_us, "{label} {op:?}");
        assert_eq!(op.resident(), op.cold_fetches == 0, "{label} {op:?}");
        assert!(
            ["compute", "bandwidth", "free"].contains(&op.bound()),
            "{label} {op:?}"
        );
        hits += op.hits;
        cold += op.cold_fetches;
        cold_bytes += op.cold_bytes;
        writeback_bytes += op.writeback_bytes;
    }
    assert_eq!(hits, mem.stats.hits, "{label}: per-op hits disagree");
    assert_eq!(cold, mem.stats.cold_fetches, "{label}: per-op colds disagree");
    assert_eq!(cold_bytes, mem.stats.cold_bytes, "{label}: cold bytes disagree");
    assert_eq!(
        writeback_bytes, mem.stats.writeback_bytes,
        "{label}: write-back bytes disagree"
    );
}

/// Assert every memory-model invariant on one module.
fn check_invariants(est: &Estimator, module: &ModuleInfo, label: &str) {
    let report = est.estimate_module(module);
    let base = schedule_estimate(module, &report, EngineConfig::Tpu);

    // Infinite buffer + infinite bandwidth: bit-identical to the
    // compute-only schedule.
    let inf = schedule_estimate_memory(
        module,
        &report,
        EngineConfig::Tpu,
        &MemoryConfig::infinite(),
    );
    assert_eq!(
        inf.makespan_us().to_bits(),
        base.makespan_us.to_bits(),
        "{label}: infinite memory config diverged from the compute-only schedule"
    );
    assert_eq!(inf.dma_busy_us(), 0.0, "{label}");
    assert_eq!(inf.ops.len(), base.ops.len(), "{label}");

    let hbm = est.hbm_bytes_per_us();
    let unbounded = schedule_estimate_memory(
        module,
        &report,
        EngineConfig::Tpu,
        &MemoryConfig::new(hbm, None),
    );
    check_rows(&unbounded, label);

    for cap in [0u64, 64 << 10, 1 << 20, 32 << 20] {
        let cfg = MemoryConfig::new(hbm, Some(cap));
        let mem = schedule_estimate_memory(module, &report, EngineConfig::Tpu, &cfg);
        // The exact bracket.
        assert!(
            base.makespan_us <= mem.makespan_us(),
            "{label} (cap {cap}): memory-aware makespan {} beat compute-only {}",
            mem.makespan_us(),
            base.makespan_us
        );
        assert!(
            mem.makespan_us() <= mem.serialized_bound_us,
            "{label} (cap {cap}): makespan {} exceeds serialized bound {}",
            mem.makespan_us(),
            mem.serialized_bound_us
        );
        assert!(
            mem.critical_path_us() <= mem.makespan_us(),
            "{label} (cap {cap}): critical path above the makespan"
        );
        // Residency bounds: zero buffer never hits, no buffer out-hits
        // the unbounded one, and first-touch traffic is the floor.
        if cap == 0 {
            assert_eq!(mem.stats.hits, 0, "{label}: hits with a zero buffer");
        }
        assert!(
            mem.stats.hits <= unbounded.stats.hits,
            "{label} (cap {cap}): {} hits beat the unbounded buffer's {}",
            mem.stats.hits,
            unbounded.stats.hits
        );
        assert!(
            mem.stats.cold_bytes >= unbounded.stats.cold_bytes,
            "{label} (cap {cap}): cold traffic below the first-touch floor"
        );
        check_rows(&mem, label);
    }
}

#[test]
fn prop_random_dags_bracketed_and_consistent() {
    let mut prng = Prng::new(4242);
    let est = estimator();
    for case in 0..25 {
        let d = 64 * (1 + prng.index(4));
        let text = random_dag_module(&mut prng, d);
        let module = parse_module(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        check_invariants(&est, &module, &format!("random case {case}"));
    }
}

#[test]
fn prop_all_mlir_fixtures_bracketed_and_consistent() {
    let est = estimator();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("mlir") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let module = parse_module(&text).unwrap();
        check_invariants(&est, &module, path.file_name().unwrap().to_str().unwrap());
        seen += 1;
    }
    assert!(seen >= 3, "expected the checked-in fixtures, saw {seen}");
}

#[test]
fn prop_hits_monotone_in_buffer_size_for_uniform_tensors() {
    // 128x128xf32 = 64 KiB per tensor, uniform across the module: LRU is
    // a stack algorithm here, so hits are monotone in capacity.
    let mut prng = Prng::new(77);
    let est = estimator();
    let tensor = 128 * 128 * 4u64;
    let caps: Vec<Option<u64>> = vec![
        Some(0),
        Some(tensor),
        Some(2 * tensor),
        Some(3 * tensor),
        Some(5 * tensor),
        Some(16 * tensor),
        None,
    ];
    for case in 0..12 {
        let text = random_dag_module(&mut prng, 128);
        let module = parse_module(&text).unwrap();
        let report = est.estimate_module(&module);
        let mut last_hits = 0usize;
        let mut last_cold = u64::MAX;
        for cap in &caps {
            let mem = schedule_estimate_memory(
                &module,
                &report,
                EngineConfig::Tpu,
                &MemoryConfig::new(est.hbm_bytes_per_us(), *cap),
            );
            assert!(
                mem.stats.hits >= last_hits,
                "case {case}: hits dropped from {last_hits} to {} at cap {cap:?}",
                mem.stats.hits
            );
            assert!(
                mem.stats.cold_bytes <= last_cold,
                "case {case}: cold traffic grew at cap {cap:?}"
            );
            last_hits = mem.stats.hits;
            last_cold = mem.stats.cold_bytes;
        }
    }
}

#[test]
fn distributed_memory_brackets_and_infinite_identity() {
    let est = estimator();
    let text = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bert_layer.mlir"),
    )
    .unwrap();
    let module = parse_module(&text).unwrap();
    for chips in [1usize, 4] {
        let slice = SliceConfig::ring(chips, 100.0);
        let plain = estimate_module_distributed(&est, &module, &slice);
        // Infinite config: the memory-aware walk is bit-identical to the
        // memory-blind one — totals, busy split and critical path.
        let inf =
            estimate_module_distributed_memory(&est, &module, &slice, &MemoryConfig::infinite());
        assert_eq!(inf.total_us.to_bits(), plain.total_us.to_bits(), "{chips} chips");
        assert_eq!(inf.compute_us.to_bits(), plain.compute_us.to_bits());
        assert_eq!(inf.collective_us.to_bits(), plain.collective_us.to_bits());
        assert_eq!(
            inf.critical_path_us.to_bits(),
            plain.critical_path_us.to_bits()
        );
        assert_eq!(inf.dma_us, 0.0);
        // A finite config pays real HBM traffic and can only slow the
        // per-chip timeline down.
        let mem = estimate_module_distributed_memory(
            &est,
            &module,
            &slice,
            &MemoryConfig::new(est.hbm_bytes_per_us(), Some(32 << 20)),
        );
        assert!(mem.dma_us > 0.0, "{chips} chips: no HBM traffic modeled");
        assert!(
            mem.total_us >= plain.total_us,
            "{chips} chips: memory-aware {} beat memory-blind {}",
            mem.total_us,
            plain.total_us
        );
        assert!(mem.critical_path_us <= mem.total_us);
        for op in &mem.ops {
            assert!(op.dma_us >= 0.0 && op.start_us <= op.finish_us, "{op:?}");
        }
    }
}

#[test]
fn smaller_hbm_bandwidth_never_speeds_up_the_module() {
    let est = estimator();
    let text = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bert_layer.mlir"),
    )
    .unwrap();
    let module = parse_module(&text).unwrap();
    let report = est.estimate_module(&module);
    let mut last = f64::INFINITY;
    // Bandwidth sweep from starved to generous: makespan is monotone
    // non-increasing in bandwidth.
    for bw in [1e4f64, 1e5, 1e6, 1e7] {
        let mem = schedule_estimate_memory(
            &module,
            &report,
            EngineConfig::Tpu,
            &MemoryConfig::new(bw, Some(32 << 20)),
        );
        assert!(
            mem.makespan_us() <= last,
            "makespan grew with bandwidth at {bw}"
        );
        last = mem.makespan_us();
    }
}
