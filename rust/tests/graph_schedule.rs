//! Scheduler invariants over random DAGs and every checked-in fixture,
//! plus the golden timeline on the BERT-layer fixture.
//!
//! The load-bearing properties:
//!
//! * `critical_path <= scheduled makespan <= unfused sum` for every
//!   engine configuration (the schedule is bracketed by the dependence
//!   bound below and the serial sum above);
//! * the serialized single-engine schedule is **bit-identical** to the
//!   unfused `estimate_module` total (the acceptance anchor);
//! * slack is non-negative and zero on the chain that realizes the
//!   makespan.

use std::path::Path;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::Estimator;
use scalesim_tpu::distributed::{estimate_module_distributed, SliceConfig};
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::graph::{schedule_module, DepGraph, Engine, EngineConfig, ModuleSchedule};
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};
use scalesim_tpu::util::prng::Prng;

fn estimator() -> Estimator {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
}

/// A random type-consistent DAG over square `DxD` f32 tensors: each op
/// draws its operands uniformly from the arguments and all earlier
/// results, mixing MXU (dot), VPU (add/multiply/maximum/tanh) and DMA
/// (transpose) work.
fn random_dag_module(prng: &mut Prng) -> String {
    let d = 64 * (1 + prng.index(4));
    let n_ops = 4 + prng.index(12);
    let mut vals: Vec<String> = vec!["a".into(), "b".into()];
    let mut body = String::new();
    for i in 0..n_ops {
        let x = vals[prng.index(vals.len())].clone();
        let y = vals[prng.index(vals.len())].clone();
        let line = match prng.index(6) {
            0 => format!(
                "    %v{i} = stablehlo.dot_general %{x}, %{y}, contracting_dims = [1] x [0] : (tensor<{d}x{d}xf32>, tensor<{d}x{d}xf32>) -> tensor<{d}x{d}xf32>\n"
            ),
            1 => format!("    %v{i} = stablehlo.add %{x}, %{y} : tensor<{d}x{d}xf32>\n"),
            2 => format!("    %v{i} = stablehlo.multiply %{x}, %{y} : tensor<{d}x{d}xf32>\n"),
            3 => format!("    %v{i} = stablehlo.maximum %{x}, %{y} : tensor<{d}x{d}xf32>\n"),
            4 => format!("    %v{i} = stablehlo.tanh %{x} : tensor<{d}x{d}xf32>\n"),
            _ => format!(
                "    %v{i} = stablehlo.transpose %{x}, dims = [1, 0] : (tensor<{d}x{d}xf32>) -> tensor<{d}x{d}xf32>\n"
            ),
        };
        body.push_str(&line);
        vals.push(format!("v{i}"));
    }
    let last = vals.last().unwrap();
    format!(
        "module @rand_dag {{\n  func.func @main(%a: tensor<{d}x{d}xf32>, %b: tensor<{d}x{d}xf32>) -> tensor<{d}x{d}xf32> {{\n{body}    return %{last} : tensor<{d}x{d}xf32>\n  }}\n}}"
    )
}

/// Assert every scheduler invariant on one module.
fn check_invariants(est: &Estimator, module: &ModuleInfo, label: &str) {
    let unfused = est.estimate_module(module);

    // The serialized single-engine schedule IS the unfused sum.
    let serialized = schedule_module(est, module, EngineConfig::Serialized);
    assert_eq!(
        serialized.makespan_us.to_bits(),
        unfused.total_us.to_bits(),
        "{label}: serialized schedule diverged from the unfused sum"
    );
    assert_eq!(serialized.ops.len(), unfused.ops.len(), "{label}");

    for config in [EngineConfig::ComputeIci, EngineConfig::Tpu] {
        let sched = schedule_module(est, module, config);
        assert!(
            sched.critical_path_us <= sched.makespan_us,
            "{label} ({}): critical path {} > makespan {}",
            config.name(),
            sched.critical_path_us,
            sched.makespan_us
        );
        assert!(
            sched.makespan_us <= unfused.total_us,
            "{label} ({}): makespan {} > unfused sum {}",
            config.name(),
            sched.makespan_us,
            unfused.total_us
        );
        check_schedule_consistency(module, &sched, label);
    }
}

/// Structural validity: dependences respected, slack sane, makespan is
/// the max finish, engine busy/idle adds up.
fn check_schedule_consistency(module: &ModuleInfo, sched: &ModuleSchedule, label: &str) {
    let max_end = sched
        .ops
        .iter()
        .fold(0.0f64, |acc, o| acc.max(o.end_us));
    assert_eq!(
        max_end.to_bits(),
        sched.makespan_us.to_bits(),
        "{label}: makespan is not the last finish"
    );
    for op in &sched.ops {
        assert!(op.start_us >= 0.0 && op.end_us >= op.start_us, "{label} {op:?}");
        assert!(op.slack_us >= 0.0, "{label} {op:?}");
        assert!(
            op.end_us + op.slack_us <= sched.makespan_us + 1e-9,
            "{label}: slack past the makespan: {op:?}"
        );
    }
    // At least one op realizes the makespan with zero slack.
    if !sched.ops.is_empty() && sched.makespan_us > 0.0 {
        assert!(
            sched.ops.iter().any(|o| o.critical()),
            "{label}: no critical op"
        );
    }
    // Dependences: every op starts at or after each producer's finish
    // (only checkable when node ids == op ids, i.e. no call inlining —
    // true for all modules exercised here).
    if let Some(func) = module.entry() {
        if func.ops.len() == sched.ops.len() {
            let graph = DepGraph::build(func);
            for (i, op) in sched.ops.iter().enumerate() {
                for &p in &graph.preds[i] {
                    assert!(
                        op.start_us >= sched.ops[p].end_us,
                        "{label}: op {i} starts before producer {p}"
                    );
                }
            }
        }
    }
    for u in &sched.engines {
        assert!(u.busy_us >= 0.0 && u.idle_us >= 0.0, "{label} {u:?}");
        let span = u.busy_us + u.idle_us;
        assert!(
            span <= sched.makespan_us + 1e-9,
            "{label}: engine span {span} exceeds makespan {}",
            sched.makespan_us
        );
        let util = u.utilization();
        assert!((0.0..=1.0).contains(&util), "{label}: utilization {util}");
    }
}

#[test]
fn prop_random_dags_bracketed_and_consistent() {
    let mut prng = Prng::new(2026);
    let est = estimator();
    for case in 0..30 {
        let text = random_dag_module(&mut prng);
        let module = parse_module(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        check_invariants(&est, &module, &format!("random case {case}"));
    }
}

#[test]
fn prop_all_mlir_fixtures_bracketed_and_consistent() {
    let est = estimator();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("mlir") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let module = parse_module(&text).unwrap();
        check_invariants(&est, &module, path.file_name().unwrap().to_str().unwrap());
        seen += 1;
    }
    assert!(seen >= 3, "expected the checked-in fixtures, saw {seen}");
}

#[test]
fn distributed_schedule_is_bracketed_too() {
    let est = estimator();
    let text = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bert_layer.mlir"),
    )
    .unwrap();
    let module = parse_module(&text).unwrap();
    for chips in [1usize, 4, 8] {
        let d = estimate_module_distributed(&est, &module, &SliceConfig::ring(chips, 100.0));
        assert!(
            d.critical_path_us <= d.total_us,
            "{chips} chips: critical {} > makespan {}",
            d.critical_path_us,
            d.total_us
        );
        // The slice timeline can never be slower than fully serializing
        // its own busy time.
        assert!(d.total_us <= d.compute_us + d.collective_us + 1e-9);
    }
}

/// Golden timeline on the BERT-layer fixture: the engine assignment of
/// all 33 ops is pinned, MXU busy time is bit-identical to the
/// estimator's systolic total, and the schedule strictly beats the
/// serial sum (transposes/reshapes overlap the projection matmuls).
#[test]
fn golden_timeline_bert_layer() {
    let est = estimator();
    let text = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bert_layer.mlir"),
    )
    .unwrap();
    let module = parse_module(&text).unwrap();
    let unfused = est.estimate_module(&module);
    let sched = schedule_module(&est, &module, EngineConfig::Tpu);

    let engines: Vec<&str> = sched
        .ops
        .iter()
        .map(|o| o.engine.map(|e| e.name()).unwrap_or("-"))
        .collect();
    #[rustfmt::skip]
    let golden = vec![
        "mxu", "mxu", "mxu",                      // q/k/v projections
        "dma", "dma", "dma", "dma", "dma", "dma", // head reshapes + transposes
        "mxu",                                    // scores (batched dot)
        "-", "dma", "vpu",                        // scale constant, broadcast, divide
        "-", "vpu", "dma", "vpu", "vpu",          // softmax max/sub/exp
        "-", "vpu", "dma", "vpu",                 // softmax sum/normalize
        "mxu", "dma", "dma", "mxu",               // context, re-layout, output proj
        "vpu",                                    // residual 1
        "mxu", "-", "dma", "vpu",                 // FFN up + relu
        "mxu", "vpu",                             // FFN down + residual 2
    ];
    assert_eq!(engines, golden, "engine assignment drifted");

    // MXU busy time is exactly the estimator's systolic share.
    let mxu = sched.usage(Engine::Mxu).unwrap();
    assert_eq!(mxu.busy_us.to_bits(), unfused.systolic_us.to_bits());
    assert_eq!(mxu.ops, 8);

    // Real overlap: DMA/VPU work hides under the matmuls.
    assert!(
        sched.makespan_us < unfused.total_us,
        "no overlap on bert_layer: {} vs {}",
        sched.makespan_us,
        unfused.total_us
    );
    assert!(sched.critical_path_us <= sched.makespan_us);

    // The final residual add closes the module: it finishes last and
    // sits on the critical chain.
    let last = sched.ops.last().unwrap();
    assert_eq!(last.op_name, "stablehlo.add");
    assert_eq!(last.end_us.to_bits(), sched.makespan_us.to_bits());
    assert_eq!(last.slack_us, 0.0);

    // The rendered timeline is stable in structure.
    let timeline = sched.render_timeline();
    assert!(timeline.starts_with("timeline @bert_layer (tpu engines)"));
    for needle in ["stablehlo.dot_general", "engine mxu", "engine vpu", "engine dma", "*"] {
        assert!(timeline.contains(needle), "timeline missing '{needle}':\n{timeline}");
    }
    // 1 header + 33 ops + 4 engine summary lines.
    assert_eq!(timeline.lines().count(), 38);
}
