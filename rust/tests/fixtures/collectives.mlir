module @collectives attributes {mhlo.num_partitions = 4 : i32} {
  func.func public @main(%arg0: tensor<1024x1024xf32>, %arg1: tensor<256x1024xf32>) -> (tensor<1024x1024xf32>) {
    %0 = "stablehlo.all_reduce"(%arg0) ({
    ^bb0(%a: tensor<f32>, %b: tensor<f32>):
      %s = stablehlo.add %a, %b : tensor<f32>
      stablehlo.return %s : tensor<f32>
    }) {replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>, channel_handle = #stablehlo.channel_handle<handle = 1, type = 1>} : (tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
    %1 = "stablehlo.all_gather"(%arg1) {all_gather_dim = 0 : i64, replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>} : (tensor<256x1024xf32>) -> tensor<1024x1024xf32>
    %2 = "stablehlo.reduce_scatter"(%0) ({
    ^bb0(%a: tensor<f32>, %b: tensor<f32>):
      %s = stablehlo.add %a, %b : tensor<f32>
      stablehlo.return %s : tensor<f32>
    }) {scatter_dimension = 0 : i64, replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>} : (tensor<1024x1024xf32>) -> tensor<256x1024xf32>
    %3 = "stablehlo.collective_permute"(%1) {source_target_pairs = dense<[[0, 1], [1, 2], [2, 3], [3, 0]]> : tensor<4x2xi64>} : (tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
    %4 = stablehlo.multiply %2, %2 : tensor<256x1024xf32>
    %5 = stablehlo.dot_general %3, %0, contracting_dims = [1] x [0] : (tensor<1024x1024xf32>, tensor<1024x1024xf32>) -> tensor<1024x1024xf32>
    return %5 : tensor<1024x1024xf32>
  }
}
