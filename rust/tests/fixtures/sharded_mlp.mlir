module @sharded_mlp attributes {mhlo.num_partitions = 4 : i32} {
  func.func public @main(%arg0: tensor<512x1024xbf16> {mhlo.sharding = "{devices=[4,1]<=[4]}"}, %arg1: tensor<1024x2048xbf16> {mhlo.sharding = "{replicated}"}, %arg2: tensor<512x2048xbf16>) -> (tensor<512x2048xbf16>) {
    %0 = stablehlo.dot_general %arg0, %arg1, contracting_dims = [1] x [0] {mhlo.sharding = "{devices=[4,1]<=[4]}"} : (tensor<512x1024xbf16>, tensor<1024x2048xbf16>) -> tensor<512x2048xbf16>
    %1 = stablehlo.add %0, %arg2 {mhlo.sharding = "{devices=[4,1]<=[4]}"} : tensor<512x2048xbf16>
    %2 = stablehlo.tanh %1 {mhlo.sharding = "{replicated}"} : tensor<512x2048xbf16>
    return %2 : tensor<512x2048xbf16>
  }
}
