module @decoder_block attributes {mhlo.num_partitions = 1 : i32} {
  func.func public @main(%x: tensor<256x1024xbf16>, %wq: tensor<1024x1024xbf16>, %wk: tensor<1024x1024xbf16>, %wv: tensor<1024x1024xbf16>, %wo: tensor<1024x1024xbf16>, %w1: tensor<1024x4096xbf16>, %w2: tensor<4096x1024xbf16>) -> (tensor<256x1024xbf16>) {
    %q = stablehlo.dot_general %x, %wq, contracting_dims = [1] x [0], precision = [DEFAULT, DEFAULT] : (tensor<256x1024xbf16>, tensor<1024x1024xbf16>) -> tensor<256x1024xbf16>
    %k = stablehlo.dot_general %x, %wk, contracting_dims = [1] x [0], precision = [DEFAULT, DEFAULT] : (tensor<256x1024xbf16>, tensor<1024x1024xbf16>) -> tensor<256x1024xbf16>
    %v = stablehlo.dot_general %x, %wv, contracting_dims = [1] x [0], precision = [DEFAULT, DEFAULT] : (tensor<256x1024xbf16>, tensor<1024x1024xbf16>) -> tensor<256x1024xbf16>
    %q3 = stablehlo.reshape %q : (tensor<256x1024xbf16>) -> tensor<256x8x128xbf16>
    %qt = stablehlo.transpose %q3, dims = [1, 0, 2] : (tensor<256x8x128xbf16>) -> tensor<8x256x128xbf16>
    %k3 = stablehlo.reshape %k : (tensor<256x1024xbf16>) -> tensor<256x8x128xbf16>
    %kt = stablehlo.transpose %k3, dims = [1, 2, 0] : (tensor<256x8x128xbf16>) -> tensor<8x128x256xbf16>
    %v3 = stablehlo.reshape %v : (tensor<256x1024xbf16>) -> tensor<256x8x128xbf16>
    %vt = stablehlo.transpose %v3, dims = [1, 0, 2] : (tensor<256x8x128xbf16>) -> tensor<8x256x128xbf16>
    %scores = stablehlo.dot_general %qt, %kt, batching_dims = [0] x [0], contracting_dims = [2] x [1] : (tensor<8x256x128xbf16>, tensor<8x128x256xbf16>) -> tensor<8x256x256xbf16>
    %cst = stablehlo.constant dense<1.131371e+01> : tensor<bf16>
    %scaleb = stablehlo.broadcast_in_dim %cst, dims = [] : (tensor<bf16>) -> tensor<8x256x256xbf16>
    %scaled = stablehlo.divide %scores, %scaleb : tensor<8x256x256xbf16>
    %cst_0 = stablehlo.constant dense<-6.550400e+04> : tensor<bf16>
    %max = stablehlo.reduce(%scaled init: %cst_0) applies stablehlo.maximum across dimensions = [2] : (tensor<8x256x256xbf16>, tensor<bf16>) -> tensor<8x256xbf16>
    %maxb = stablehlo.broadcast_in_dim %max, dims = [0, 1] : (tensor<8x256xbf16>) -> tensor<8x256x256xbf16>
    %sub = stablehlo.subtract %scaled, %maxb : tensor<8x256x256xbf16>
    %exp = stablehlo.exponential %sub : tensor<8x256x256xbf16>
    %cst_1 = stablehlo.constant dense<0.000000e+00> : tensor<bf16>
    %sum = stablehlo.reduce(%exp init: %cst_1) applies stablehlo.add across dimensions = [2] : (tensor<8x256x256xbf16>, tensor<bf16>) -> tensor<8x256xbf16>
    %sumb = stablehlo.broadcast_in_dim %sum, dims = [0, 1] : (tensor<8x256xbf16>) -> tensor<8x256x256xbf16>
    %probs = stablehlo.divide %exp, %sumb : tensor<8x256x256xbf16>
    %ctx = stablehlo.dot_general %probs, %vt, batching_dims = [0] x [0], contracting_dims = [2] x [1] : (tensor<8x256x256xbf16>, tensor<8x256x128xbf16>) -> tensor<8x256x128xbf16>
    %ctxt = stablehlo.transpose %ctx, dims = [1, 0, 2] : (tensor<8x256x128xbf16>) -> tensor<256x8x128xbf16>
    %ctx2 = stablehlo.reshape %ctxt : (tensor<256x8x128xbf16>) -> tensor<256x1024xbf16>
    %attn = stablehlo.dot_general %ctx2, %wo, contracting_dims = [1] x [0] : (tensor<256x1024xbf16>, tensor<1024x1024xbf16>) -> tensor<256x1024xbf16>
    %res1 = stablehlo.add %attn, %x : tensor<256x1024xbf16>
    %ffn1 = stablehlo.dot_general %res1, %w1, contracting_dims = [1] x [0] : (tensor<256x1024xbf16>, tensor<1024x4096xbf16>) -> tensor<256x4096xbf16>
    %cst_2 = stablehlo.constant dense<0.000000e+00> : tensor<bf16>
    %zb = stablehlo.broadcast_in_dim %cst_2, dims = [] : (tensor<bf16>) -> tensor<256x4096xbf16>
    %relu = stablehlo.maximum %ffn1, %zb : tensor<256x4096xbf16>
    %ffn2 = stablehlo.dot_general %relu, %w2, contracting_dims = [1] x [0] : (tensor<256x4096xbf16>, tensor<4096x1024xbf16>) -> tensor<256x1024xbf16>
    %res2 = stablehlo.add %ffn2, %res1 : tensor<256x1024xbf16>
    return %res2 : tensor<256x1024xbf16>
  }
}
