#!/usr/bin/env python3
"""Bit-exact replica of `scalesim-tpu llm --module decoder_block.mlir --phase-csv`.

Regenerates tests/fixtures/llm_phases.csv, the per-preset prefill/decode
phase table for the decoder-block fixture. The Rust CLI must reproduce
this file byte for byte (tests/cli.rs::llm_phase_csv_matches_golden
asserts it); if the decoder fixture, the device presets, the estimator
cost model, the dependence-graph scheduler or the DMA-timeline residency
walk change intentionally, re-run this script and commit the fixture
together with the change.

Replicated arithmetic (all IEEE-754 double, matching the Rust ops 1:1):
  * classify + per-class estimator costs (src/frontend/classify.rs,
    src/coordinator/estimator.rs): systolic GEMM via the SCALE-Sim WS
    fold model, elementwise fallback 3x output bytes, reduction
    input+output bytes, data movement 2x moved bytes, each at
    bandwidth_us(b) = 0.5 + b / hbm_bytes_per_us;
  * the synthetic sweep calibration latency = 1e-3 * cycles * count
    (src/sweep/mod.rs::sweep_estimator);
  * the DMA timeline: LRU residency with pinned operands, cold fetches,
    dirty evictions, spills and the `return` escape
    (src/memory/timeline.rs, residency.rs);
  * the list scheduler over MXU/VPU/DMA lanes (src/graph/schedule.rs)
    and the aggregate roofline verdict (src/graph/analysis.rs);
  * the decode lowering seq 256 -> 1 (src/inference/lower.rs) and the
    KV bytes/token formula 2*layers*kv_heads*head_dim*dtype
    (src/inference/kv.rs).
"""

import math
import os

# name, (SR, SC), (if_bw, fl_bw, of_bw), hbm_gbps, vmem_bytes
PRESETS = [
    ("tpu-v4", (128, 128), (256.0, 256.0, 128.0), 1200.0, 32 * 1024 * 1024),
    ("tpu-v5e", (128, 128), (176.0, 176.0, 88.0), 819.0, 16 * 1024 * 1024),
    ("tpu-v5p", (128, 128), (512.0, 512.0, 256.0), 2765.0, 64 * 1024 * 1024),
    ("generic-256x256", (256, 256), (128.0, 128.0, 64.0), 600.0,
     24 * 1024 * 1024),
]

SEQ = 256  # leading dim of %x in decoder_block.mlir
BF16 = 2

# The decoder-block entry function, transcribed op for op from
# decoder_block.mlir. `S` marks every extent equal to the sequence dim;
# the decode lowering rewrites S -> 1 and nothing else (exactly what
# rewrite_seq does: weights and head extents carry no 256).
# kind: gemm(m,k,n,count) | dm | ew | red | free | ret
S = "S"


def dims(spec, s):
    return tuple(s if d == S else d for d in spec)


ARG_DIMS = {
    "x": (S, 1024),
    "wq": (1024, 1024),
    "wk": (1024, 1024),
    "wv": (1024, 1024),
    "wo": (1024, 1024),
    "w1": (1024, 4096),
    "w2": (4096, 1024),
}

# (result, kind, operands, out_dims, extra)
#   gemm extra: (m, k, n, count) with S placeholders
#   red  extra: input dims
OPS = [
    ("q", "gemm", ["x", "wq"], (S, 1024), (S, 1024, 1024, 1)),
    ("k", "gemm", ["x", "wk"], (S, 1024), (S, 1024, 1024, 1)),
    ("v", "gemm", ["x", "wv"], (S, 1024), (S, 1024, 1024, 1)),
    ("q3", "dm", ["q"], (S, 8, 128), None),
    ("qt", "dm", ["q3"], (8, S, 128), None),
    ("k3", "dm", ["k"], (S, 8, 128), None),
    ("kt", "dm", ["k3"], (8, 128, S), None),
    ("v3", "dm", ["v"], (S, 8, 128), None),
    ("vt", "dm", ["v3"], (8, S, 128), None),
    ("scores", "gemm", ["qt", "kt"], (8, S, S), (S, 128, S, 8)),
    ("cst", "free", [], (), None),
    ("scaleb", "dm", ["cst"], (8, S, S), None),
    ("scaled", "ew", ["scores", "scaleb"], (8, S, S), None),
    ("cst_0", "free", [], (), None),
    ("max", "red", ["scaled", "cst_0"], (8, S), (8, S, S)),
    ("maxb", "dm", ["max"], (8, S, S), None),
    ("sub", "ew", ["scaled", "maxb"], (8, S, S), None),
    ("exp", "ew", ["sub"], (8, S, S), None),
    ("cst_1", "free", [], (), None),
    ("sum", "red", ["exp", "cst_1"], (8, S), (8, S, S)),
    ("sumb", "dm", ["sum"], (8, S, S), None),
    ("probs", "ew", ["exp", "sumb"], (8, S, S), None),
    ("ctx", "gemm", ["probs", "vt"], (8, S, 128), (S, S, 128, 8)),
    ("ctxt", "dm", ["ctx"], (S, 8, 128), None),
    ("ctx2", "dm", ["ctxt"], (S, 1024), None),
    ("attn", "gemm", ["ctx2", "wo"], (S, 1024), (S, 1024, 1024, 1)),
    ("res1", "ew", ["attn", "x"], (S, 1024), None),
    ("ffn1", "gemm", ["res1", "w1"], (S, 4096), (S, 1024, 4096, 1)),
    ("cst_2", "free", [], (), None),
    ("zb", "dm", ["cst_2"], (S, 4096), None),
    ("relu", "ew", ["ffn1", "zb"], (S, 4096), None),
    ("ffn2", "gemm", ["relu", "w2"], (S, 1024), (S, 4096, 1024, 1)),
    ("res2", "ew", ["ffn2", "res1"], (S, 1024), None),
    (None, "ret", ["res2"], None, None),
]

ENGINE = {"gemm": "mxu", "ew": "vpu", "red": "vpu", "dm": "dma"}


def ceil_div(a, b):
    return -(-a // b)


def nbytes(d):
    return math.prod(d) * BF16 if d is not None else 0


def ws_fold_classes(k, n, sr, sc):
    """SCALE-Sim WS fold decomposition: rows=K, cols=N."""
    rf, cf = ceil_div(k, sr), ceil_div(n, sc)
    last_r = k - (rf - 1) * sr
    last_c = n - (cf - 1) * sc
    classes = []
    if (rf - 1) * (cf - 1) > 0:
        classes.append(((sr, sc), (rf - 1) * (cf - 1)))
    if cf - 1 > 0:
        classes.append(((last_r, sc), cf - 1))
    if rf - 1 > 0:
        classes.append(((sr, last_c), rf - 1))
    classes.append(((last_r, last_c), 1))
    return classes


def simulate_ws(m, k, n, arr, bw):
    """total_cycles of simulate_gemm under a WS config."""
    sr, sc = arr
    if_bw, fl_bw, of_bw = bw
    compute = 0
    stall = 0
    initial = 0
    first = True
    for (r, c), count in ws_fold_classes(k, n, sr, sc):
        t_compute = r + (r + c + m - 2)  # load + stream
        compute += t_compute * count
        if_w, fl_w, of_w = m * r, r * c, m * c
        t_read = max(math.ceil(if_w / if_bw), math.ceil(fl_w / fl_bw))
        t_write = math.ceil(of_w / of_bw)
        remaining = count
        if first:
            initial = t_read
            first = False
            remaining -= 1
        stall += max(0, max(t_read, t_write) - t_compute) * remaining
    return initial + compute + stall


def op_cost(kind, extra, out_d, s, arr, bw, hbm):
    if kind in ("free", "ret"):
        return 0.0
    if kind == "gemm":
        m, k, n, count = dims(extra, s)
        cycles = simulate_ws(m, k, n, arr, bw)
        return max((1e-3 * cycles + 0.0) * float(count), 0.0)
    if kind == "ew":
        return 0.5 + nbytes(out_d) * 3 / hbm
    if kind == "red":
        in_b = nbytes(dims(extra, s))
        return 0.5 + (in_b + nbytes(out_d)) / hbm
    if kind == "dm":
        return 0.5 + nbytes(out_d) * 2 / hbm
    raise AssertionError(kind)


class Tracker:
    """LRU residency with pinned values (src/memory/residency.rs)."""

    def __init__(self, cap):
        self.cap = cap
        self.entries = {}  # id -> [bytes, dirty]
        self.order = []
        self.used = 0

    def access(self, vid):
        if vid in self.entries:
            self.order.remove(vid)
            self.order.append(vid)
            return True
        return False

    def contains(self, vid):
        return vid in self.entries

    def insert(self, vid, b, dirty, pinned):
        if vid in self.entries:
            e = self.entries[vid]
            e[1] = e[1] or dirty
            self.order.remove(vid)
            self.order.append(vid)
            return True, []
        if self.cap is not None:
            if b > self.cap:
                return False, []
            if self.used + b > self.cap:
                need = self.used + b - self.cap
                freed = 0
                victims = []
                for cand in self.order:
                    if freed >= need:
                        break
                    if cand in pinned:
                        continue
                    freed += self.entries[cand][0]
                    victims.append(cand)
                if freed < need:
                    return False, []
                evicted = []
                for v in victims:
                    vb, vd = self.entries.pop(v)
                    self.used -= vb
                    self.order.remove(v)
                    evicted.append((v, vb, vd))
                self.entries[vid] = [b, dirty]
                self.order.append(vid)
                self.used += b
                return True, evicted
        self.entries[vid] = [b, dirty]
        self.order.append(vid)
        self.used += b
        return True, []

    def remove(self, vid):
        if vid in self.entries:
            self.used -= self.entries.pop(vid)[0]
            self.order.remove(vid)


def push_unique(v, n):
    if n not in v:
        v.append(n)


def schedule(s, arr, bw, hbm, vmem):
    """Replica of schedule_module_memory: (makespan_us, verdict)."""
    # --- value registration (DmaTimeline::new) ---
    values = {}  # id -> [bytes, uses, chip_node, hbm_node, dirty]
    for res, _, _, out_d, _ in OPS:
        if res is not None:
            values[res] = [nbytes(dims(out_d, s)), 0, None, None, False]
    for _, _, operands, _, _ in OPS:
        seen = []
        for o in operands:
            if o in seen:
                continue
            seen.append(o)
            if o not in values:
                values[o] = [nbytes(dims(ARG_DIMS[o], s)), 0, None, None,
                             False]
            values[o][1] += 1

    tracker = Tracker(vmem)
    producer = {res: i for i, (res, _, _, _, _) in enumerate(OPS)
                if res is not None}
    nodes = []  # (engine, cost, preds)
    provider = []
    per_op = []  # (compute_us, dma_us) in op order

    for i, (res, kind, operands, out_d, extra) in enumerate(OPS):
        ded = []
        for o in operands:
            if o not in ded:
                ded.append(o)

        # --- fetch (skipped for return) ---
        fetch_node = None
        fetch_us = 0.0
        hit_preds = []
        if kind != "ret":
            fetch_preds = []
            cold_ids = []
            written_back = []
            cold_bytes = 0
            wb_bytes = 0
            for vid in ded:
                b, _, chip, hbm_node, _ = values[vid]
                if b == 0:
                    continue
                if tracker.access(vid):
                    if chip is not None:
                        push_unique(hit_preds, chip)
                else:
                    cold_bytes += b
                    if hbm_node is not None:
                        push_unique(fetch_preds, hbm_node)
                    inserted, evicted = tracker.insert(vid, b, False, ded)
                    if inserted:
                        cold_ids.append(vid)
                    for ev_id, ev_b, ev_dirty in evicted:
                        if ev_dirty:
                            wb_bytes += ev_b
                            if values[ev_id][2] is not None:
                                push_unique(fetch_preds, values[ev_id][2])
                            values[ev_id][4] = False
                            written_back.append(ev_id)
            total = cold_bytes + wb_bytes
            if total > 0:
                cost = total / hbm
                fetch_node = len(nodes)
                nodes.append(("dma" if cost > 0.0 else None, cost,
                              fetch_preds))
                for vid in cold_ids:
                    values[vid][2] = fetch_node
                for vid in written_back:
                    values[vid][3] = fetch_node
                fetch_us = cost

        # --- compute node ---
        cost = op_cost(kind, extra, dims(out_d, s) if out_d else None, s,
                       arr, bw, hbm)
        engine = ENGINE.get(kind)
        preds = []
        gpreds = []
        for o in operands:
            if o in producer and producer[o] not in gpreds:
                gpreds.append(producer[o])
        for p in gpreds:
            push_unique(preds, provider[p])
        for n in hit_preds:
            push_unique(preds, n)
        if fetch_node is not None:
            push_unique(preds, fetch_node)
        main = len(nodes)
        nodes.append((engine, cost, preds))
        provider.append(main)

        # --- retire ---
        retire_us = 0.0
        r_preds = [main]
        r_bytes = 0
        hbm_updates = []
        if kind == "ret":
            for vid in ded:
                b, _, chip, _, dirty = values[vid]
                if b > 0 and dirty and tracker.contains(vid):
                    r_bytes += b
                    if chip is not None:
                        push_unique(r_preds, chip)
                    hbm_updates.append(vid)
        for vid in ded:
            values[vid][1] = max(0, values[vid][1] - 1)
            if values[vid][1] == 0:
                tracker.remove(vid)
        if res is not None:
            rb, uses = values[res][0], values[res][1]
            if rb > 0 and uses > 0:
                inserted, evicted = tracker.insert(res, rb, True, [res])
                if inserted:
                    values[res][2] = main
                    values[res][4] = True
                    for ev_id, ev_b, ev_dirty in evicted:
                        if ev_dirty:
                            r_bytes += ev_b
                            if values[ev_id][2] is not None:
                                push_unique(r_preds, values[ev_id][2])
                            values[ev_id][4] = False
                            hbm_updates.append(ev_id)
                else:
                    r_bytes += rb
                    values[res][4] = False
                    hbm_updates.append(res)
        if r_bytes > 0:
            cost_out = r_bytes / hbm
            node_id = len(nodes)
            nodes.append(("dma" if cost_out > 0.0 else None, cost_out,
                          r_preds))
            for vid in hbm_updates:
                values[vid][3] = node_id
            retire_us = cost_out

        per_op.append((cost, fetch_us + retire_us))

    # --- list scheduler (src/graph/schedule.rs::place) ---
    lane_free = {}
    ends = []
    for engine, cost, preds in nodes:
        ready = 0.0
        for p in preds:
            ready = max(ready, ends[p])
        if engine is not None:
            start = max(ready, lane_free.get(engine, 0.0))
        else:
            start = ready
        end = start + cost
        if engine is not None:
            lane_free[engine] = end
        ends.append(end)
    makespan = 0.0
    for e in ends:
        makespan = max(makespan, e)

    # --- roofline (src/graph/analysis.rs) ---
    compute_us = 0.0
    dma_us = 0.0
    for c, d in per_op:
        compute_us += c
        dma_us += d
    verdict = "bandwidth-bound" if dma_us > compute_us else "compute-bound"
    return makespan, verdict


def kv_bytes_per_token():
    # 2 * layers * kv_heads * head_dim * dtype; heads from the first
    # [seq, d] -> [seq, h, hd] reshape (q3: 8 x 128), bf16 activations.
    return 2 * 1 * 8 * 128 * BF16


def main():
    out = ["device,seq,prefill_us,prefill_verdict,decode_us,decode_verdict,"
           "kv_bytes_per_token"]
    for name, arr, bw, hbm_gbps, vmem in PRESETS:
        hbm = hbm_gbps * 1e3
        p_us, p_v = schedule(SEQ, arr, bw, hbm, vmem)
        d_us, d_v = schedule(1, arr, bw, hbm, vmem)
        out.append(f"{name},{SEQ},{p_us:.6f},{p_v},{d_us:.6f},{d_v},"
                   f"{kv_bytes_per_token()}")
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "llm_phases.csv")
    with open(path, "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote {path} ({len(out) - 1} rows)")
    for line in out:
        print(line)


if __name__ == "__main__":
    main()
