#!/usr/bin/env python3
"""Bit-exact replica of `scalesim-tpu sweep --device tpu-v4 --grid small --csv`.

Regenerates tests/fixtures/sweep_small_tpu-v4.csv. The Rust CLI must
reproduce this file byte for byte (tests/cli.rs::sweep_golden_csv_matches
asserts it); if the sweep grids, the simulate_gemm arithmetic, or the
tpu-v4 preset change intentionally, re-run this script and commit the
fixture together with the change.

Replicated arithmetic (all IEEE-754 double, matching the Rust ops 1:1):
  * compute_model + memory_model for the tpu-v4 WS 128x128 config
    (src/scalesim/dataflow.rs, memory.rs),
  * the synthetic sweep calibration latency = 1e-3 * cycles
    (src/sweep/mod.rs::sweep_estimator),
  * bandwidth_us(bytes) = 0.5 + bytes / (1200.0 * 1e3)
    (src/coordinator/estimator.rs).
"""

import math
import os

SR, SC = 128, 128            # tpu-v4 MXU array
IF_BW, FL_BW, OF_BW = 256.0, 256.0, 128.0
HBM_BYTES_PER_US = 1200.0 * 1e3


def ceil_div(a, b):
    return -(-a // b)


def ws_fold_classes(k, n):
    """SCALE-Sim WS fold decomposition: rows=K, cols=N."""
    rf, cf = ceil_div(k, SR), ceil_div(n, SC)
    last_r = k - (rf - 1) * SR
    last_c = n - (cf - 1) * SC
    classes = []
    if (rf - 1) * (cf - 1) > 0:
        classes.append(((SR, SC), (rf - 1) * (cf - 1)))
    if cf - 1 > 0:
        classes.append(((last_r, SC), cf - 1))
    if rf - 1 > 0:
        classes.append(((SR, last_c), rf - 1))
    classes.append(((last_r, last_c), 1))
    return classes


def simulate_ws(m, k, n):
    """total_cycles of simulate_gemm under the tpu-v4 WS config."""
    compute = 0
    stall = 0
    initial = 0
    first = True
    for (r, c), count in ws_fold_classes(k, n):
        t_compute = r + (r + c + m - 2)  # load + stream
        compute += t_compute * count
        if_w, fl_w, of_w = m * r, r * c, m * c
        t_read = max(math.ceil(if_w / IF_BW), math.ceil(fl_w / FL_BW))
        t_write = math.ceil(of_w / OF_BW)
        remaining = count
        if first:
            initial = t_read
            first = False
            remaining -= 1
        stall += max(0, max(t_read, t_write) - t_compute) * remaining
    return initial + compute + stall


def bandwidth_us(nbytes):
    return 0.5 + nbytes / HBM_BYTES_PER_US


def fmt(x):
    return f"{x:.6f}"


DTYPE_BYTES = {"bf16": 2, "f32": 4}


def rows():
    out = []

    def systolic(cls, op, shape, m, k, n):
        cycles = simulate_ws(m, k, n)
        nbytes = (m * k + k * n + m * n) * 2
        out.append((cls, op, shape, "bf16", nbytes, "systolic", str(cycles),
                    fmt(1e-3 * cycles)))

    def bandwidth_row(cls, op, shape, dtype, nbytes, source):
        out.append((cls, op, shape, dtype, nbytes, source, "",
                    fmt(bandwidth_us(nbytes))))

    # matmul (grid.rs::matmul_cases, Small)
    for m, k, n in [(64, 64, 64), (128, 128, 128), (256, 256, 256),
                    (512, 512, 512), (128, 1024, 128), (1024, 128, 1024)]:
        systolic("matmul", "dot_general", f"{m}x{k}x{n}", m, k, n)

    # conv (grid.rs::conv_cases, Small): im2col M=out_h*out_w,
    # K=fh*fw*channels, N=num_filters.
    for ih, iw, fh, fw, c, nf, s in [(32, 32, 3, 3, 16, 32, 1),
                                     (28, 28, 5, 5, 8, 16, 2)]:
        oh = (ih - fh) // s + 1
        ow = (iw - fw) // s + 1
        systolic("conv", "convolution", f"{ih}x{iw}x{c}/{fh}x{fw}/f{nf}/s{s}",
                 oh * ow, fh * fw * c, nf)

    # elementwise: no learned models in the sweep estimator -> fallback,
    # charged 3x the output footprint.
    for op in ["add", "multiply", "maximum"]:
        for dims in [[1024], [128, 128], [64, 512]]:
            elems = math.prod(dims)
            shape = "x".join(str(d) for d in dims)
            bandwidth_row("elementwise", op, shape, "bf16", elems * 2 * 3,
                          "fallback")

    # activation (same fallback model)
    for op in ["exponential", "tanh", "logistic"]:
        for dims in [[128, 128], [32, 1024]]:
            elems = math.prod(dims)
            shape = "x".join(str(d) for d in dims)
            bandwidth_row("activation", op, shape, "bf16", elems * 2 * 3,
                          "fallback")

    # normalization: reduction charged input + output bytes.
    for ind, outd in [([128, 1024], [128]), ([256, 256], [256])]:
        nbytes = (math.prod(ind) + math.prod(outd)) * 4
        shape = "x".join(map(str, ind)) + "->" + "x".join(map(str, outd))
        bandwidth_row("normalization", "reduce", shape, "f32", nbytes,
                      "bandwidth")

    # pooling: reduce_window over [c, h, w] -> [c, h/2, w/2], bf16.
    for c, h, w in [(32, 56, 56), (64, 28, 28)]:
        nbytes = (c * h * w + c * (h // 2) * (w // 2)) * 2
        shape = f"{c}x{h}x{w}->{c}x{h // 2}x{w // 2}"
        bandwidth_row("pooling", "reduce_window", shape, "bf16", nbytes,
                      "bandwidth")

    # data-movement: read + write of the moved footprint.
    for op, dims, dtype in [("transpose", [1024, 1024], "f32"),
                            ("reshape", [8, 4096], "bf16")]:
        nbytes = math.prod(dims) * DTYPE_BYTES[dtype] * 2
        shape = "x".join(str(d) for d in dims)
        bandwidth_row("data-movement", op, shape, dtype, nbytes, "bandwidth")

    return out


def main():
    lines = ["class,op,shape,dtype,bytes,source,cycles,latency_us"]
    for r in rows():
        lines.append(",".join(str(f) for f in r))
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "sweep_small_tpu-v4.csv")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {path} ({len(lines) - 1} rows)")


if __name__ == "__main__":
    main()
