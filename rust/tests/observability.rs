//! Observability integration: the Chrome trace renderers against a
//! hand-authored golden fixture and the trace-event schema.
//!
//! The golden test pins the exact JSON the scheduler's trace renderer
//! emits for a three-op diamond whose placement is computable by hand
//! (MXU 10 µs ∥ VPU 2 µs → VPU 1 µs join: makespan 11 µs, the side
//! branch carries 8 µs of slack). The schema tests then run the real
//! BERT-layer fixture through the deterministic sweep-calibrated
//! estimator and validate every emitted event against the trace-event
//! format Perfetto/`chrome://tracing` consume — required keys, `X`
//! durations, lanes declared via `thread_name` metadata — plus
//! renderer determinism (same schedule, byte-identical trace).

use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::graph::analysis::finish_schedule;
use scalesim_tpu::graph::{Engine, EngineConfig, SchedNode};
use scalesim_tpu::memory::schedule_estimate_memory;
use scalesim_tpu::obs::{trace_json, TraceEvent};
use scalesim_tpu::sweep::sweep_estimator;
use scalesim_tpu::util::json::Json;

fn fixture(name: &str) -> String {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

/// The hand-schedulable diamond behind the golden fixture.
fn mini_schedule_nodes() -> Vec<SchedNode> {
    vec![
        SchedNode {
            index: 0,
            op_name: "attn_qk".into(),
            engine: Some(Engine::Mxu),
            cost_us: 10.0,
            preds: vec![],
            source: "systolic",
            note: String::new(),
        },
        SchedNode {
            index: 1,
            op_name: "bias_add".into(),
            engine: Some(Engine::Vpu),
            cost_us: 2.0,
            preds: vec![],
            source: "free",
            note: "elementwise".into(),
        },
        SchedNode {
            index: 2,
            op_name: "softmax_join".into(),
            engine: Some(Engine::Vpu),
            cost_us: 1.0,
            preds: vec![0, 1],
            source: "learned",
            note: String::new(),
        },
    ]
}

#[test]
fn mini_schedule_trace_matches_golden_fixture() {
    let sched = finish_schedule("mini".into(), EngineConfig::Tpu, mini_schedule_nodes());
    assert_eq!(sched.makespan_us, 11.0);
    let got = trace_json(&sched.trace_events());
    let want = Json::parse(&fixture("mini_schedule.trace.json")).expect("fixture parses");
    assert_eq!(
        got, want,
        "trace renderer diverged from the golden fixture:\n got: {}\nwant: {}",
        got.dump(),
        want.dump()
    );
}

/// Assert one event satisfies the trace-event format: the keys every
/// viewer requires, a phase we emit, and a non-negative `X` duration.
fn check_event_schema(ev: &Json, engines: usize) {
    let name = ev.req_str("name").expect("event has name");
    let ph = ev.req_str("ph").expect("event has ph");
    assert!(ev.req_str("cat").is_ok(), "{name}: missing cat");
    assert!(ev.req_f64("ts").is_ok(), "{name}: missing ts");
    let pid = ev.req_f64("pid").expect("event has pid");
    let tid = ev.req_f64("tid").expect("event has tid");
    assert_eq!(pid, 1.0, "{name}: scheduler traces use one process");
    assert!(
        (tid as usize) < engines,
        "{name}: tid {tid} outside the declared engine lanes"
    );
    match ph {
        "X" => {
            let dur = ev.req_f64("dur").expect("X event has dur");
            assert!(dur >= 0.0, "{name}: negative duration {dur}");
        }
        "M" => {
            assert!(
                name == "process_name" || name == "thread_name",
                "unexpected metadata event {name}"
            );
            assert!(
                ev.get("args").and_then(|a| a.get("name")).is_some(),
                "{name}: metadata without args.name"
            );
        }
        other => panic!("{name}: unexpected phase {other:?}"),
    }
}

#[test]
fn bert_layer_trace_is_schema_valid_and_deterministic() {
    let module = parse_module(&fixture("bert_layer.mlir")).expect("bert fixture parses");
    let est = sweep_estimator(&DeviceSpec::tpu_v4());
    let report = est.estimate_module(&module);
    let engines = EngineConfig::Tpu;
    let mem = schedule_estimate_memory(
        &module,
        &report,
        engines,
        &DeviceSpec::tpu_v4().memory_config(),
    );

    let events = mem.trace_events();
    let lanes = engines.engines().len();

    // Lane metadata: exactly one process_name, one thread_name per
    // engine of the config, declared before any slice uses the lane.
    let names: Vec<&TraceEvent> = events.iter().filter(|e| e.ph == 'M').collect();
    assert_eq!(names.len(), 1 + lanes);
    assert_eq!(names[0].name, "process_name");

    // Every event passes the schema check after a JSON round-trip (the
    // same bytes `--trace-out` writes).
    let json = trace_json(&events);
    let arr = json.req_arr("traceEvents").expect("traceEvents array");
    assert!(arr.len() > 1 + lanes, "no op slices rendered");
    for ev in arr {
        check_event_schema(ev, lanes);
    }

    // The memory-aware renderer keeps the DMA sub-slices visible and
    // flags a critical chain for the viewer to highlight.
    let cats: Vec<&str> = events.iter().map(|e| e.cat.as_str()).collect();
    assert!(
        cats.iter().any(|c| c.ends_with(",critical")),
        "no critical-path slice in the BERT trace"
    );
    assert!(
        events.iter().any(|e| e.name.ends_with(".dma_in")),
        "memory-aware trace lost its dma_in sub-slices"
    );

    // Determinism: rendering the same schedule twice is byte-identical.
    let again = trace_json(&mem.trace_events());
    assert_eq!(json.dump(), again.dump());
}
