//! Exact-invariant battery for the LLM serving simulator.
//!
//! Every assertion here is epsilon-free: bit-identity via `to_bits()`,
//! exact `<=` / `>=` on the float clock, and byte-identity on rendered
//! output. The simulator is deterministic by construction (seeded
//! workload, pure float arithmetic, no wall clock), so any drift in the
//! phase model, the KV accounting or the event loop fails here first.
//! All properties are checked across every device preset and several
//! seeds.

use std::path::Path;

use scalesim_tpu::coordinator::Estimator;
use scalesim_tpu::device::{DeviceSpec, PRESET_NAMES};
use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::inference::{
    generate_workload, phase_csv, simulate, standalone_request, KvCacheSpec, PhaseModel,
    SimConfig, WorkloadConfig,
};
use scalesim_tpu::sweep::sweep_estimator;

const SEEDS: [u64; 3] = [7, 42, 1234];

fn fixture_text() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/decoder_block.mlir");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

fn setup(device: &str) -> (Estimator, PhaseModel, KvCacheSpec) {
    let spec = DeviceSpec::preset(device).unwrap();
    let est = sweep_estimator(&spec);
    let module = parse_module(&fixture_text()).unwrap();
    let phase = PhaseModel::new(&est, &module).expect("decoder block has a sequence extent");
    let kv = KvCacheSpec::infer(&module, 1).expect("decoder block has a head-split reshape");
    (est, phase, kv)
}

fn workload_cfg(seed: u64) -> WorkloadConfig {
    WorkloadConfig {
        seed,
        ..WorkloadConfig::default()
    }
}

/// A single-request stream through the full continuous-batching loop is
/// bit-identical to running the request standalone (prefill then
/// decode, no batching). `RequestResult` derives `PartialEq` over f64
/// fields, so this is exact float equality — no epsilon.
#[test]
fn single_request_stream_is_bit_identical_to_standalone() {
    for device in PRESET_NAMES {
        let (est, mut phase, kv) = setup(device);
        for seed in SEEDS {
            let wl = generate_workload(&WorkloadConfig {
                requests: 1,
                ..workload_cfg(seed)
            });
            let cfg = SimConfig::default();
            let report = simulate(&est, &mut phase, &kv, &wl, &cfg);
            assert_eq!(report.requests.len(), 1);
            let solo = standalone_request(&est, &mut phase, &kv, &wl[0], cfg.kv_capacity);
            assert_eq!(
                report.requests[0], solo,
                "{device} seed {seed}: stream diverged from standalone"
            );
            assert_eq!(
                report.requests[0].completion_us.to_bits(),
                solo.completion_us.to_bits(),
                "{device} seed {seed}: completion not bit-identical"
            );
        }
    }
}

/// Per-request causality: a request can never see its first token
/// before it arrives, finish before its first token, or report a TTFT
/// above its end-to-end latency.
#[test]
fn ttft_is_bounded_by_latency_for_every_request() {
    for device in PRESET_NAMES {
        let (est, mut phase, kv) = setup(device);
        for seed in SEEDS {
            let wl = generate_workload(&workload_cfg(seed));
            let report = simulate(&est, &mut phase, &kv, &wl, &SimConfig::default());
            assert_eq!(report.requests.len(), wl.len());
            for r in &report.requests {
                assert!(r.ttft_us >= 0.0, "{device} seed {seed} req {}: {r:?}", r.id);
                assert!(
                    r.ttft_us <= r.latency_us,
                    "{device} seed {seed} req {}: ttft {} > latency {}",
                    r.id,
                    r.ttft_us,
                    r.latency_us
                );
                assert!(r.first_token_us >= r.arrival_us);
                assert!(r.completion_us >= r.first_token_us);
            }
            // Order statistics inherit the per-request bound exactly.
            assert!(report.ttft_p50_us() <= report.latency_p50_us());
        }
    }
}

/// Arriving later never makes a standalone request finish earlier: both
/// first-token and completion times are monotone in arrival time.
#[test]
fn later_arrival_is_monotone_for_standalone_requests() {
    for device in PRESET_NAMES {
        let (est, mut phase, kv) = setup(device);
        for seed in SEEDS {
            let wl = generate_workload(&workload_cfg(seed));
            for r in &wl {
                let base = standalone_request(&est, &mut phase, &kv, r, None);
                let mut later_spec = *r;
                later_spec.arrival_us += 500.0;
                let later = standalone_request(&est, &mut phase, &kv, &later_spec, None);
                assert!(
                    later.first_token_us >= base.first_token_us,
                    "{device} seed {seed} req {}: first token moved earlier",
                    r.id
                );
                assert!(
                    later.completion_us >= base.completion_us,
                    "{device} seed {seed} req {}: completion moved earlier",
                    r.id
                );
            }
        }
    }
}

/// Continuous batching can only help: with KV unbounded, the batched
/// makespan never exceeds the serialized (max_batch = 1) makespan of
/// the same stream.
#[test]
fn batching_never_beats_by_losing_makespan() {
    for device in PRESET_NAMES {
        let (est, mut phase, kv) = setup(device);
        for seed in SEEDS {
            let wl = generate_workload(&workload_cfg(seed));
            let batched = simulate(
                &est,
                &mut phase,
                &kv,
                &wl,
                &SimConfig {
                    max_batch: 8,
                    kv_capacity: None,
                },
            );
            let serial = simulate(
                &est,
                &mut phase,
                &kv,
                &wl,
                &SimConfig {
                    max_batch: 1,
                    kv_capacity: None,
                },
            );
            assert!(
                batched.makespan_us <= serial.makespan_us,
                "{device} seed {seed}: batched {} > serialized {}",
                batched.makespan_us,
                serial.makespan_us
            );
        }
    }
}

/// Measured throughput never exceeds the decode roofline bound
/// `1e6 · max_batch / decode_step_us` — under the default arrival gap
/// and under a fully saturated (gap 0) stream.
#[test]
fn tokens_per_sec_never_exceeds_the_roofline() {
    for device in PRESET_NAMES {
        let (est, mut phase, kv) = setup(device);
        for seed in SEEDS {
            for gap in [200.0, 0.0] {
                let wl = generate_workload(&WorkloadConfig {
                    requests: 32,
                    mean_gap_us: gap,
                    ..workload_cfg(seed)
                });
                let report = simulate(&est, &mut phase, &kv, &wl, &SimConfig::default());
                assert!(
                    report.tokens_per_sec <= report.roofline_tokens_per_sec,
                    "{device} seed {seed} gap {gap}: {} > roofline {}",
                    report.tokens_per_sec,
                    report.roofline_tokens_per_sec
                );
                assert!(report.tokens_per_sec > 0.0);
            }
        }
    }
}

/// KV accounting, exact in all three regimes: an unbounded budget never
/// spills; a budget of exactly the observed peak reproduces the
/// unbounded run bit for bit; a budget far below the working set spills
/// but still completes every request — and no regime ever evicts,
/// because KV is pinned.
#[test]
fn kv_spill_accounting_is_exact_in_all_regimes() {
    for device in PRESET_NAMES {
        let (est, mut phase, kv) = setup(device);
        for seed in SEEDS {
            let wl = generate_workload(&workload_cfg(seed));

            let unbounded = simulate(
                &est,
                &mut phase,
                &kv,
                &wl,
                &SimConfig {
                    max_batch: 8,
                    kv_capacity: None,
                },
            );
            assert_eq!(unbounded.kv_spill_events, 0, "{device} seed {seed}");
            assert_eq!(unbounded.kv_spilled_bytes, 0, "{device} seed {seed}");
            assert_eq!(unbounded.kv_evictions, 0, "{device} seed {seed}");
            assert!(unbounded.kv_peak_bytes > 0);

            // A budget of exactly the peak is enough: zero spills and a
            // bit-identical makespan.
            let exact = simulate(
                &est,
                &mut phase,
                &kv,
                &wl,
                &SimConfig {
                    max_batch: 8,
                    kv_capacity: Some(unbounded.kv_peak_bytes),
                },
            );
            assert_eq!(exact.kv_spill_events, 0, "{device} seed {seed}");
            assert_eq!(
                exact.makespan_us.to_bits(),
                unbounded.makespan_us.to_bits(),
                "{device} seed {seed}: peak-sized budget changed the clock"
            );

            // A budget of one request's 64-token cache is far below the
            // default stream's working set: it must spill, never evict,
            // and still finish everything.
            let tight = simulate(
                &est,
                &mut phase,
                &kv,
                &wl,
                &SimConfig {
                    max_batch: 8,
                    kv_capacity: Some(kv.bytes_at(64)),
                },
            );
            assert!(tight.kv_spill_events > 0, "{device} seed {seed}");
            assert!(tight.kv_spilled_bytes > 0, "{device} seed {seed}");
            assert_eq!(tight.kv_evictions, 0, "{device} seed {seed}");
            assert_eq!(tight.requests.len(), wl.len(), "{device} seed {seed}");
            assert!(
                tight.makespan_us >= unbounded.makespan_us,
                "{device} seed {seed}: spilling made the stream faster"
            );
        }
    }
}

/// The whole report is deterministic: the same seed renders the same
/// JSON payload byte for byte (BTreeMap key order + exact float
/// formatting), across repeated runs and fresh phase models.
#[test]
fn same_seed_renders_byte_identical_json() {
    for device in PRESET_NAMES {
        for seed in SEEDS {
            let run = || {
                let (est, mut phase, kv) = setup(device);
                let wl = generate_workload(&workload_cfg(seed));
                simulate(&est, &mut phase, &kv, &wl, &SimConfig::default())
                    .to_json()
                    .dump()
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{device} seed {seed}: JSON drifted across runs");
            assert!(a.contains("\"requests_detail\""));
        }
    }
}

/// The per-preset phase table regenerates byte-identically against the
/// checked-in golden produced by the independent Python replica
/// (`tests/fixtures/gen_llm_golden.py`) — prefill/decode costs, both
/// roofline verdicts and the KV bytes-per-token for all four presets.
#[test]
fn phase_csv_matches_the_checked_in_golden() {
    let module = parse_module(&fixture_text()).unwrap();
    assert_eq!(
        phase_csv(&module),
        include_str!("fixtures/llm_phases.csv"),
        "phase table drifted from the golden fixture"
    );
}
