//! Failure injection: every external input the system consumes —
//! artifacts, model files, IR text, requests — corrupted or missing, must
//! produce a clean error (never a panic, never silent garbage).

use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::learned::Hgbr;
use scalesim_tpu::runtime::Runtime;
use scalesim_tpu::scalesim::Topology;
use scalesim_tpu::util::json::Json;

fn tmp(name: &str, content: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("scalesim_failure_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    std::fs::write(&p, content).unwrap();
    p
}

#[test]
fn corrupt_stablehlo_is_an_error_not_a_panic() {
    for text in [
        "",
        "module {",
        "module { func.func @main( }",
        "garbage % @ # <<<",
        "module { func.func @main() -> tensor<4xf32> { %0 = stablehlo.add %1 ",
        // Dynamic shapes rejected explicitly.
        "module { func.func @main(%a: tensor<?x4xf32>) -> tensor<4xf32> { return %a : tensor<4xf32> } }",
    ] {
        let r = parse_module(text);
        assert!(r.is_err(), "should reject: {text:?}");
    }
}

#[test]
fn corrupt_model_json_rejected() {
    for content in [
        "not json at all",
        "{}",
        r#"{"base": 1.0}"#,
        r#"{"base": 1.0, "learning_rate": 0.1, "feature_names": [], "trees": [{"nodes": []}]}"#,
    ] {
        let p = tmp("bad_model.json", content);
        assert!(Hgbr::load(&p).is_err(), "should reject: {content}");
    }
}

#[test]
fn corrupt_hlo_artifact_rejected_by_runtime() {
    // Offline builds stub PJRT out; client construction failing cleanly
    // (not panicking) is itself the failure-injection contract here.
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: PJRT runtime unavailable (build with --features pjrt)");
        return;
    };
    let p = tmp("bad.hlo.txt", "HloModule broken\nENTRY main { this is not hlo }");
    assert!(rt.compile_file(&p).is_err());
    let missing = std::env::temp_dir().join("scalesim_failure_tests/nonexistent.hlo.txt");
    assert!(rt.compile_file(&missing).is_err());
}

#[test]
fn corrupt_topology_csv_rejected() {
    for text in [
        "layer, 1, 2\n",                 // wrong arity
        "conv, 8, 8, 9, 9, 1, 1, 1,\n",  // filter > ifmap
        "g, 0, 1, 1,\n",                 // zero dim
        // Non-numeric rows after the (single allowed) header line.
        "h1, 1, 1, 1,\nconv, a, b, c, d, e, f, g,\n",
    ] {
        assert!(Topology::parse_csv("x", text).is_err(), "{text:?}");
    }
    // But headers/comments/blank lines are tolerated.
    let ok = Topology::parse_csv("x", "# comment\n\nLayer, IFMAP H, ...\nfc, 4, 4, 4,\n");
    assert!(ok.is_ok());
}

#[test]
fn malformed_service_requests_answered_with_errors() {
    use scalesim_tpu::calibrate::fit_regime_calibration;
    use scalesim_tpu::coordinator::{serve_lines, Estimator};
    use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};

    let mut obs = Vec::new();
    for d in [32usize, 64, 128, 256, 512, 1024, 2048, 4096] {
        obs.push((GemmShape::new(d, d, d), (d * d) as u64, d as f64));
    }
    let est = std::sync::Arc::new(Estimator::new(
        ScaleConfig::tpu_v4(),
        fit_regime_calibration(&obs).unwrap(),
    ));
    let lines: Vec<String> = vec![
        "not json".into(),
        r#"{"type":"gemm"}"#.into(),                         // missing dims
        r#"{"type":"gemm","m":-1,"k":2,"n":3}"#.into(),      // negative
        r#"{"type":"elementwise","op":"nonsense","dims":[4]}"#.into(),
        r#"{"type":"module","path":"/no/such/file"}"#.into(),
    ];
    let responses = serve_lines(est, &lines, 2);
    assert_eq!(responses.len(), lines.len());
    for (line, resp) in lines.iter().zip(&responses) {
        let j = Json::parse(resp).expect("response must be valid JSON");
        assert_eq!(
            j.get("ok"),
            Some(&Json::Bool(false)),
            "should fail: {line} -> {resp}"
        );
        assert!(j.req_str("error").unwrap().len() > 3);
    }
}

#[test]
fn assets_dir_with_partial_contents_fails_loud() {
    use scalesim_tpu::experiments::assets;
    let dir = std::env::temp_dir().join("scalesim_failure_partial_assets");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    // config.json present but calibration missing.
    std::fs::write(
        dir.join("config.json"),
        scalesim_tpu::scalesim::ScaleConfig::tpu_v4().to_json().pretty(),
    )
    .unwrap();
    assert!(assets::load_assets(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
