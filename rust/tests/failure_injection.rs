//! Failure injection: every external input the system consumes —
//! artifacts, model files, IR text, requests — corrupted or missing, must
//! produce a clean error (never a panic, never silent garbage).

use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::learned::Hgbr;
use scalesim_tpu::runtime::Runtime;
use scalesim_tpu::scalesim::Topology;
use scalesim_tpu::util::json::Json;

fn tmp(name: &str, content: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("scalesim_failure_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    std::fs::write(&p, content).unwrap();
    p
}

#[test]
fn corrupt_stablehlo_is_an_error_not_a_panic() {
    for text in [
        "",
        "module {",
        "module { func.func @main( }",
        "garbage % @ # <<<",
        "module { func.func @main() -> tensor<4xf32> { %0 = stablehlo.add %1 ",
        // Dynamic shapes rejected explicitly.
        "module { func.func @main(%a: tensor<?x4xf32>) -> tensor<4xf32> { return %a : tensor<4xf32> } }",
    ] {
        let r = parse_module(text);
        assert!(r.is_err(), "should reject: {text:?}");
    }
}

#[test]
fn corrupt_model_json_rejected() {
    for content in [
        "not json at all",
        "{}",
        r#"{"base": 1.0}"#,
        r#"{"base": 1.0, "learning_rate": 0.1, "feature_names": [], "trees": [{"nodes": []}]}"#,
    ] {
        let p = tmp("bad_model.json", content);
        assert!(Hgbr::load(&p).is_err(), "should reject: {content}");
    }
}

#[test]
fn corrupt_hlo_artifact_rejected_by_runtime() {
    // Offline builds stub PJRT out; client construction failing cleanly
    // (not panicking) is itself the failure-injection contract here.
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: PJRT runtime unavailable (build with --features pjrt)");
        return;
    };
    let p = tmp("bad.hlo.txt", "HloModule broken\nENTRY main { this is not hlo }");
    assert!(rt.compile_file(&p).is_err());
    let missing = std::env::temp_dir().join("scalesim_failure_tests/nonexistent.hlo.txt");
    assert!(rt.compile_file(&missing).is_err());
}

#[test]
fn corrupt_topology_csv_rejected() {
    for text in [
        "layer, 1, 2\n",                 // wrong arity
        "conv, 8, 8, 9, 9, 1, 1, 1,\n",  // filter > ifmap
        "g, 0, 1, 1,\n",                 // zero dim
        // Non-numeric rows after the (single allowed) header line.
        "h1, 1, 1, 1,\nconv, a, b, c, d, e, f, g,\n",
    ] {
        assert!(Topology::parse_csv("x", text).is_err(), "{text:?}");
    }
    // But headers/comments/blank lines are tolerated.
    let ok = Topology::parse_csv("x", "# comment\n\nLayer, IFMAP H, ...\nfc, 4, 4, 4,\n");
    assert!(ok.is_ok());
}

#[test]
fn malformed_service_requests_answered_with_errors() {
    use scalesim_tpu::calibrate::fit_regime_calibration;
    use scalesim_tpu::coordinator::{serve_lines, Estimator};
    use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};

    let mut obs = Vec::new();
    for d in [32usize, 64, 128, 256, 512, 1024, 2048, 4096] {
        obs.push((GemmShape::new(d, d, d), (d * d) as u64, d as f64));
    }
    let est = std::sync::Arc::new(Estimator::new(
        ScaleConfig::tpu_v4(),
        fit_regime_calibration(&obs).unwrap(),
    ));
    let lines: Vec<String> = vec![
        "not json".into(),
        r#"{"type":"gemm"}"#.into(),                         // missing dims
        r#"{"type":"gemm","m":-1,"k":2,"n":3}"#.into(),      // negative
        r#"{"type":"elementwise","op":"nonsense","dims":[4]}"#.into(),
        r#"{"type":"module","path":"/no/such/file"}"#.into(),
    ];
    let responses = serve_lines(est, &lines, 2);
    assert_eq!(responses.len(), lines.len());
    for (line, resp) in lines.iter().zip(&responses) {
        let j = Json::parse(resp).expect("response must be valid JSON");
        assert_eq!(
            j.get("ok"),
            Some(&Json::Bool(false)),
            "should fail: {line} -> {resp}"
        );
        assert!(j.req_str("error").unwrap().len() > 3);
    }
}

#[test]
fn assets_dir_with_partial_contents_fails_loud() {
    use scalesim_tpu::experiments::assets;
    let dir = std::env::temp_dir().join("scalesim_failure_partial_assets");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    // config.json present but calibration missing.
    std::fs::write(
        dir.join("config.json"),
        scalesim_tpu::scalesim::ScaleConfig::tpu_v4().to_json().pretty(),
    )
    .unwrap();
    assert!(assets::load_assets(&dir).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Network fault injection: a hostile or dying client must never wedge the
// pool, poison the shared cache, or stall other connections.
// ---------------------------------------------------------------------------

mod net_failures {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{Shutdown, SocketAddr, TcpStream};
    use std::sync::Arc;
    use std::time::Duration;

    use scalesim_tpu::coordinator::{
        serve_lines, Estimator, NetOptions, NetServer, NetSummary, ShutdownHandle,
    };
    use scalesim_tpu::device::DeviceSpec;
    use scalesim_tpu::sweep::sweep_estimator;
    use scalesim_tpu::util::json::Json;

    fn spawn_server(
        opts: NetOptions,
    ) -> (
        SocketAddr,
        ShutdownHandle,
        std::thread::JoinHandle<NetSummary>,
        Arc<Estimator>,
    ) {
        let est = Arc::new(sweep_estimator(&DeviceSpec::tpu_v4()));
        let server = NetServer::bind("127.0.0.1:0", Arc::clone(&est), opts).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = server.shutdown_handle();
        let join = std::thread::spawn(move || server.run().unwrap());
        (addr, handle, join, est)
    }

    fn gemm_line(d: usize) -> String {
        format!("{{\"type\":\"gemm\",\"m\":{d},\"k\":{d},\"n\":{d}}}")
    }

    #[test]
    fn malformed_line_mid_stream_errors_and_connection_continues() {
        let (addr, handle, join, _est) = spawn_server(NetOptions::default());
        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "{}", gemm_line(128)).unwrap();
        writeln!(conn, "{{not json % garbage").unwrap();
        writeln!(conn, "{}", gemm_line(256)).unwrap();
        conn.flush().unwrap();
        conn.shutdown(Shutdown::Write).unwrap();

        // All three lines are answered in order; the garbage line gets a
        // structured error and the connection keeps serving afterwards.
        let lines: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 3);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).expect("response must be valid JSON");
            assert_eq!(j.req_f64("id").unwrap(), i as f64, "out of order: {line}");
            let ok = j.get("ok") == Some(&Json::Bool(true));
            if i == 1 {
                assert!(!ok, "garbage must fail: {line}");
                assert!(j.req_str("error").unwrap().len() > 3);
            } else {
                assert!(ok, "good request must survive a bad neighbor: {line}");
            }
        }

        handle.shutdown();
        let summary = join.join().unwrap();
        assert_eq!(summary.stream.requests, 3);
        assert_eq!(summary.stream.ok, 2);
        assert_eq!(summary.stream.errors, 1);
    }

    #[test]
    fn client_disconnect_mid_request_does_not_wedge_pool_or_cache() {
        let (addr, handle, join, _est) = spawn_server(NetOptions::default());
        let lines: Vec<String> = (0..50).map(|i| gemm_line(32 + 16 * (i % 8))).collect();

        // Client 1 fires 50 requests and vanishes without reading a byte.
        {
            let mut conn = TcpStream::connect(addr).unwrap();
            for line in &lines {
                writeln!(conn, "{line}").unwrap();
            }
            conn.flush().unwrap();
        } // dropped here: responses hit a dead socket

        // Client 2 must still get complete, correct service over the same
        // shared cache the dead client warmed.
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        for line in &lines {
            writeln!(conn, "{line}").unwrap();
        }
        conn.flush().unwrap();
        conn.shutdown(Shutdown::Write).unwrap();
        let responses: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
        let baseline = serve_lines(Arc::new(sweep_estimator(&DeviceSpec::tpu_v4())), &lines, 1);
        assert_eq!(responses, baseline, "cache poisoned or pool wedged by dead client");

        handle.shutdown();
        let summary = join.join().unwrap();
        // The live connection's 50 requests are fully accounted for. The
        // dead client's reader may stop early once its writer notices the
        // lost socket, so its count is bounded, not exact — but every
        // counted request resolved to exactly one of ok/error.
        assert_eq!(summary.connections, 2);
        assert!(summary.stream.requests >= 50 && summary.stream.requests <= 100);
        assert!(summary.stream.ok >= 50);
        assert_eq!(summary.stream.ok + summary.stream.errors, summary.stream.requests);
    }

    #[test]
    fn slow_reader_does_not_stall_other_connections() {
        // Small in-flight cap so the slow connection saturates its own
        // lane quickly instead of flooding the pool.
        let (addr, handle, join, _est) = spawn_server(NetOptions {
            workers: 4,
            inflight: 8,
            ..NetOptions::default()
        });

        // Slow client: 200 requests, reads nothing yet.
        let slow = TcpStream::connect(addr).unwrap();
        let slow_wr = std::thread::spawn({
            let mut wr = slow.try_clone().unwrap();
            move || {
                for i in 0..200 {
                    writeln!(wr, "{}", gemm_line(32 + 16 * (i % 12))).unwrap();
                }
                wr.flush().unwrap();
                wr.shutdown(Shutdown::Write).ok();
            }
        });

        // Fast client: must stream all 100 responses promptly while the
        // slow connection sits unread. The read timeout is the hang alarm.
        let mut fast = TcpStream::connect(addr).unwrap();
        fast.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        for i in 0..100 {
            writeln!(fast, "{}", gemm_line(48 + 16 * (i % 12))).unwrap();
        }
        fast.flush().unwrap();
        fast.shutdown(Shutdown::Write).unwrap();
        let fast_responses: Vec<String> =
            BufReader::new(fast).lines().map(|l| l.unwrap()).collect();
        assert_eq!(fast_responses.len(), 100, "fast connection stalled by slow reader");
        for (i, line) in fast_responses.iter().enumerate() {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.req_f64("id").unwrap(), i as f64);
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
        }

        // Now drain the slow connection; every one of its responses must
        // still arrive, in order.
        slow_wr.join().unwrap();
        slow.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let slow_responses: Vec<String> =
            BufReader::new(slow).lines().map(|l| l.unwrap()).collect();
        assert_eq!(slow_responses.len(), 200);
        for (i, line) in slow_responses.iter().enumerate() {
            assert_eq!(Json::parse(line).unwrap().req_f64("id").unwrap(), i as f64);
        }

        handle.shutdown();
        let summary = join.join().unwrap();
        assert_eq!(summary.stream.requests, 300);
        assert_eq!(summary.stream.ok, 300);
        assert_eq!(summary.stream.errors, 0);
    }
}
