//! Exactness invariants for the build-once / re-cost-many schedule
//! template ([`ScheduleTemplate`]) and the parallel fan-outs built on
//! it. Every assertion here is bitwise — no epsilons anywhere:
//!
//! * a template re-cost at the captured extents is bit-identical to the
//!   from-scratch `schedule_module_memory` pipeline, for every device
//!   preset × every checked-in module fixture;
//! * a sequence re-cost is bit-identical to rewriting the module and
//!   rebuilding from scratch, across a prompt-length sweep;
//! * the assembled estimate rows are bit-identical to
//!   `Estimator::estimate_module` (the 1-chip regression surface);
//! * interleaved re-costs across devices and prompt lengths in shuffled
//!   call orders never contaminate each other;
//! * every parallel fan-out (`phase_csv`, `bench-llm`, the sweep
//!   multi-device runner, a distributed-estimate map) is byte-identical
//!   to its serial walk.

use scalesim_tpu::coordinator::{parallel_map, Estimator};
use scalesim_tpu::device::{DeviceSpec, PRESET_NAMES};
use scalesim_tpu::distributed::estimate_module_distributed;
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::graph::{EngineConfig, ScheduleTemplate};
use scalesim_tpu::inference::{
    phase_csv_workers, rewrite_seq, run_llm_bench, sequence_dim, LlmBenchOptions,
};
use scalesim_tpu::memory::{schedule_module_memory, MemoryConfig, MemorySchedule};
use scalesim_tpu::sweep::{run_sweep, run_sweep_devices, sweep_estimator, GridSize, SweepOpClass};

const FIXTURES: &[(&str, &str)] = &[
    (
        "decoder_block",
        include_str!("fixtures/decoder_block.mlir"),
    ),
    ("bert_layer", include_str!("fixtures/bert_layer.mlir")),
    ("collectives", include_str!("fixtures/collectives.mlir")),
    ("sharded_mlp", include_str!("fixtures/sharded_mlp.mlir")),
    (
        "while_loop",
        include_str!("fixtures/while_loop.stablehlo.txt"),
    ),
];

const PROMPTS: &[usize] = &[1, 16, 64, 96, 256, 300, 1024];

fn setup(preset: &str) -> (DeviceSpec, Estimator, EngineConfig, MemoryConfig) {
    let spec = DeviceSpec::preset(preset).expect("registered preset");
    let est = sweep_estimator(&spec);
    let engine = EngineConfig::for_device(est.device());
    let memory = MemoryConfig::new(est.hbm_bytes_per_us(), Some(est.device().vmem_bytes));
    (spec, est, engine, memory)
}

/// Bitwise schedule equality via the derived Debug rendering: Rust
/// formats every f64 as its shortest uniquely-round-tripping decimal,
/// so two schedules render identically iff every float matches bit for
/// bit (no NaNs are ever produced here) and every other field is equal.
fn assert_schedules_identical(a: &MemorySchedule, b: &MemorySchedule, what: &str) {
    assert_eq!(
        a.makespan_us().to_bits(),
        b.makespan_us().to_bits(),
        "{what}: makespan drifted"
    );
    assert_eq!(
        format!("{a:?}"),
        format!("{b:?}"),
        "{what}: schedules are not bit-identical"
    );
}

fn template_for(
    module: &ModuleInfo,
    engine: EngineConfig,
    memory: MemoryConfig,
) -> ScheduleTemplate {
    ScheduleTemplate::capture(module, engine, memory).expect("fixture captures a template")
}

#[test]
fn recost_native_is_bit_identical_to_from_scratch_everywhere() {
    for preset in PRESET_NAMES {
        for (name, text) in FIXTURES {
            let module = parse_module(text).expect(name);
            let (_, est, engine, memory) = setup(preset);
            let scratch = schedule_module_memory(&est, &module, engine, &memory);
            let template = template_for(&module, engine, memory);
            let replay = template.recost_native(&est);
            assert_schedules_identical(&scratch, &replay, &format!("{preset}/{name}"));
            assert_eq!(template.template_hits(), 1);
        }
    }
}

#[test]
fn estimate_native_matches_estimate_module_rows() {
    for preset in PRESET_NAMES {
        for (name, text) in FIXTURES {
            let module = parse_module(text).expect(name);
            let (_, est, engine, memory) = setup(preset);
            let scratch = est.estimate_module(&module);
            let template = template_for(&module, engine, memory);
            let replay = template.estimate_native(&est);
            assert_eq!(
                scratch.total_us.to_bits(),
                replay.total_us.to_bits(),
                "{preset}/{name}: total drifted"
            );
            assert_eq!(
                format!("{scratch:?}"),
                format!("{replay:?}"),
                "{preset}/{name}: estimate rows are not bit-identical"
            );
        }
    }
}

#[test]
fn recost_seq_matches_rewrite_and_rebuild_across_prompts() {
    let module = parse_module(FIXTURES[0].1).expect("decoder block");
    let seq = sequence_dim(&module).expect("sequence extent");
    for preset in PRESET_NAMES {
        let (_, est, engine, memory) = setup(preset);
        let template = template_for(&module, engine, memory);
        for &p in PROMPTS {
            let rewritten = rewrite_seq(&module, seq, p);
            let scratch = schedule_module_memory(&est, &rewritten, engine, &memory);
            let replay = template.recost_seq(&est, seq, p);
            assert_schedules_identical(&scratch, &replay, &format!("{preset}/prompt {p}"));
        }
    }
}

#[test]
fn interleaved_recosts_never_contaminate_each_other() {
    let module = parse_module(FIXTURES[0].1).expect("decoder block");
    let seq = sequence_dim(&module).expect("sequence extent");
    let devices = ["tpu-v4", "tpu-v5p", "generic-256x256"];

    // Expected value per (device, prompt), computed from scratch.
    let mut setups = Vec::new();
    let mut expected: Vec<String> = Vec::new();
    for preset in devices {
        let (_, est, engine, memory) = setup(preset);
        let template = template_for(&module, engine, memory);
        for &p in PROMPTS {
            let rewritten = rewrite_seq(&module, seq, p);
            expected.push(format!(
                "{:?}",
                schedule_module_memory(&est, &rewritten, engine, &memory)
            ));
        }
        setups.push((est, template));
    }

    // Replay the full (device × prompt) grid in several deterministic
    // shuffled orders over the *same* long-lived templates: every call
    // must still match its from-scratch expectation bit for bit, no
    // matter what was re-costed before it.
    let n = devices.len() * PROMPTS.len();
    for round in 0..4usize {
        let mut order: Vec<usize> = (0..n).collect();
        // Deterministic LCG-driven Fisher-Yates; a different
        // permutation each round.
        let mut state = 0x9e37_79b9_7f4a_7c15u64.wrapping_add(round as u64);
        for i in (1..n).rev() {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let j = (state >> 33) as usize % (i + 1);
            order.swap(i, j);
        }
        for &k in &order {
            let (d, pi) = (k / PROMPTS.len(), k % PROMPTS.len());
            let (est, template) = &setups[d];
            let got = template.recost_seq(est, seq, PROMPTS[pi]);
            assert_eq!(
                format!("{got:?}"),
                expected[k],
                "round {round}: {}/prompt {} contaminated",
                devices[d],
                PROMPTS[pi]
            );
        }
    }
}

#[test]
fn phase_csv_fanout_is_byte_identical_to_serial() {
    let module = parse_module(FIXTURES[0].1).expect("decoder block");
    let serial = phase_csv_workers(&module, 1);
    for workers in [2, 4, 8] {
        assert_eq!(
            serial,
            phase_csv_workers(&module, workers),
            "{workers} workers"
        );
    }
}

#[test]
fn llm_bench_rows_are_identical_for_any_worker_count() {
    let run = |workers: usize| {
        run_llm_bench(&LlmBenchOptions {
            requests: 6,
            workers,
            ..LlmBenchOptions::default()
        })
        .expect("bench runs")
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(serial.rows.len(), parallel.rows.len());
    for (a, b) in serial.rows.iter().zip(&parallel.rows) {
        assert_eq!(a.device, b.device);
        assert_eq!(a.tokens_per_sec.to_bits(), b.tokens_per_sec.to_bits());
        assert_eq!(a.ttft_p50_us.to_bits(), b.ttft_p50_us.to_bits());
        assert_eq!(a.tpot_mean_us.to_bits(), b.tpot_mean_us.to_bits());
        assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
        assert_eq!(a.kv_spill_events, b.kv_spill_events);
    }
    assert_eq!(serial.template_hits, parallel.template_hits);
    assert!(serial.template_hits > 0);
}

#[test]
fn sweep_device_fanout_matches_serial_run_sweep() {
    let specs: Vec<DeviceSpec> = ["tpu-v4", "tpu-v5e"]
        .iter()
        .map(|p| DeviceSpec::preset(p).unwrap())
        .collect();
    let classes = SweepOpClass::parse_list("matmul,elementwise").unwrap();
    let fanned = run_sweep_devices(&specs, &classes, GridSize::Small, 4);
    assert_eq!(fanned.len(), specs.len());
    for (spec, got) in specs.iter().zip(&fanned) {
        let est = sweep_estimator(spec);
        let serial = run_sweep(&est, &classes, GridSize::Small);
        assert_eq!(
            serial.to_csv(),
            got.to_csv(),
            "{}: fan-out drifted from serial sweep",
            spec.name
        );
        assert_eq!(format!("{:?}", serial.grid), format!("{:?}", got.grid));
        assert_eq!(serial.device, got.device);
    }
}

#[test]
fn distributed_estimates_fan_out_byte_identically() {
    let module = parse_module(FIXTURES[3].1).expect("sharded mlp");
    let specs: Vec<DeviceSpec> = PRESET_NAMES
        .iter()
        .map(|p| DeviceSpec::preset(p).unwrap())
        .collect();
    let serial: Vec<String> = specs
        .iter()
        .map(|spec| {
            let est = sweep_estimator(spec);
            let slice = spec.slice_config(4, None).expect("4-chip slice");
            format!("{:?}", estimate_module_distributed(&est, &module, &slice))
        })
        .collect();
    let parallel = parallel_map(&specs, 4, |spec| {
        let est = sweep_estimator(spec);
        let slice = spec.slice_config(4, None).expect("4-chip slice");
        format!("{:?}", estimate_module_distributed(&est, &module, &slice))
    });
    assert_eq!(serial, parallel);
}

#[test]
fn recost_over_external_costs_replays_the_native_schedule() {
    let module = parse_module(FIXTURES[0].1).expect("decoder block");
    let (_, est, engine, memory) = setup("tpu-v4");
    let template = template_for(&module, engine, memory);
    let native = template.recost_native(&est);
    // `recost` is the raw entry: feeding it the very costs the batched
    // estimator resolves must reproduce the native replay bit for bit.
    let costs = est.estimate_classes(template.native_classes());
    let replayed = template.recost(&costs);
    assert_schedules_identical(&native, &replayed, "external-cost recost");
}
