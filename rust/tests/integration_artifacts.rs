//! Integration: the real AOT artifacts produced by python/compile/aot.py.
//!
//! These tests exercise the frontend against *actual JAX output* (not
//! hand-written IR). They skip gracefully when `make artifacts` has not
//! run (e.g. a pure-Rust CI lane).

use scalesim_tpu::frontend::{classify, parse_module, OpClass};
use scalesim_tpu::scalesim::GemmShape;

fn artifact(name: &str) -> Option<String> {
    std::fs::read_to_string(format!("artifacts/{name}")).ok()
}

#[test]
fn mlp_stablehlo_parses_and_classifies() {
    let Some(text) = artifact("mlp_b32.stablehlo.txt") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let module = parse_module(&text).expect("parse mlp stablehlo");
    let func = module.entry().expect("entry");
    assert_eq!(func.arg_types.len(), 1);
    assert_eq!(func.arg_types[0].dims, vec![32, 784]);
    assert_eq!(func.result_types[0].dims, vec![32, 10]);

    // The standard lowering has exactly the 3 matmuls of the MLP.
    let gemms: Vec<GemmShape> = func
        .ops
        .iter()
        .filter_map(|op| match classify(op) {
            OpClass::SystolicGemm { gemm, .. } => Some(gemm),
            _ => None,
        })
        .collect();
    assert_eq!(
        gemms,
        vec![
            GemmShape::new(32, 784, 512),
            GemmShape::new(32, 512, 256),
            GemmShape::new(32, 256, 10),
        ]
    );
    // And the two ReLUs (maximum) + two bias adds.
    let ew = func
        .ops
        .iter()
        .filter(|op| matches!(classify(op), OpClass::Elementwise { .. }))
        .count();
    assert!(ew >= 4, "elementwise ops {ew}");
}

#[test]
fn transformer_stablehlo_parses_with_attention_gemms() {
    let Some(text) = artifact("transformer_s128_d256_h4.stablehlo.txt") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let module = parse_module(&text).expect("parse transformer stablehlo");
    let func = module.entry().expect("entry");

    let mut gemm_count = 0usize;
    let mut total_macs: u64 = 0;
    for op in &func.ops {
        if let OpClass::SystolicGemm { gemm, count } = classify(op) {
            gemm_count += 1;
            total_macs += gemm.macs() * count;
        }
    }
    // qkv/out/up/down + 2 per head (4 heads) = 12 dot_generals.
    assert!(gemm_count >= 12, "gemms {gemm_count}");
    // MAC count must match the analytic transformer topology.
    let expected = scalesim_tpu::workloads::models::transformer_block(128, 256, 4).total_macs();
    assert_eq!(total_macs, expected);

    // Softmax pieces show up as reductions + elementwise.
    let has_reduce = func
        .ops
        .iter()
        .any(|op| matches!(classify(op), OpClass::Reduction { .. }));
    assert!(has_reduce, "expected softmax reductions");
}

#[test]
fn elementwise_artifacts_classify_to_learned_path() {
    for (name, want) in [
        ("ew_add_1024x1024.stablehlo.txt", "add"),
        ("ew_relu_1024x1024.stablehlo.txt", "maximum"),
    ] {
        let Some(text) = artifact(name) else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let module = parse_module(&text).expect("parse ew stablehlo");
        let func = module.entry().unwrap();
        let found = func.ops.iter().any(|op| {
            matches!(
                classify(op),
                OpClass::Elementwise { kind, ref out }
                    if kind.name() == want && out.num_elements() == 1024 * 1024
            )
        });
        assert!(found, "{name}: no {want} op over 1024x1024");
    }
}

#[test]
fn pallas_lowered_stablehlo_of_gemm_still_parses() {
    // The *runtime* artifacts are HLO, but the Pallas path can also be
    // exported as StableHLO (call-form). The parser + estimator must not
    // choke on it: regenerate a small one inline from the hlo text is not
    // possible, so parse the mlp HLO's stablehlo sibling and ensure calls
    // are followed (callee recursion covered by unit tests).
    let Some(text) = artifact("gemm_m128_k256_n512.stablehlo.txt") else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let module = parse_module(&text).expect("parse");
    assert!(module.entry().is_some());
}
