//! CLI integration: drive the actual `scalesim-tpu` binary end to end
//! (cargo builds it for integration tests; `CARGO_BIN_EXE_*` points at it).

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_scalesim-tpu"))
        .args(args)
        .output()
        .expect("spawn scalesim-tpu");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["table1", "fig2", "fig5", "simulate", "calibrate", "serve"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn table1_prints_comparison() {
    let (stdout, _, ok) = run(&["table1"]);
    assert!(ok);
    assert!(stdout.contains("SCALE-Sim TPU (this work)"));
    assert!(stdout.contains("StableHLO"));
    assert!(stdout.contains("true"));
}

#[test]
fn simulate_single_gemm_with_extensions() {
    let trace = std::env::temp_dir().join("scalesim_cli_trace.csv");
    let (stdout, _, ok) = run(&[
        "simulate",
        "--m",
        "256",
        "--k",
        "256",
        "--n",
        "256",
        "--energy",
        "--sparsity",
        "0.5",
        "--trace",
        trace.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("GEMM 256x256x256"));
    assert!(stdout.contains("regime: medium"));
    assert!(stdout.contains("energy:"));
    assert!(stdout.contains("speedup"));
    let csv = std::fs::read_to_string(&trace).unwrap();
    assert!(csv.starts_with("fold,start_cycle"));
    std::fs::remove_file(&trace).ok();
}

#[test]
fn simulate_topology_csv() {
    let (stdout, _, ok) = run(&["simulate", "--topology", "topologies/bert_base_layer.csv"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("ffn_up"));
    assert!(stdout.contains("total:"));
}

#[test]
fn unknown_subcommand_fails_cleanly() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn bad_dataflow_rejected() {
    let (_, stderr, ok) = run(&["simulate", "--m", "8", "--k", "8", "--n", "8", "--dataflow", "zz"]);
    assert!(!ok);
    assert!(stderr.contains("bad dataflow"));
}
