//! CLI integration: drive the actual `scalesim-tpu` binary end to end
//! (cargo builds it for integration tests; `CARGO_BIN_EXE_*` points at it).
//! Every subcommand has at least one exit-status + output smoke test.

use std::path::PathBuf;
use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_scalesim-tpu"))
        .args(args)
        .output()
        .expect("spawn scalesim-tpu");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

/// A per-test scratch directory (fresh on entry, removed on drop).
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Scratch {
        let dir = std::env::temp_dir().join(format!("scalesim_cli_{tag}"));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        Scratch(dir)
    }

    fn path(&self, name: &str) -> String {
        self.0.join(name).to_str().unwrap().to_string()
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.0).ok();
    }
}

fn bert_fixture() -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/bert_layer.mlir")
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn help_lists_subcommands() {
    let (stdout, _, ok) = run(&["help"]);
    assert!(ok);
    for cmd in ["table1", "fig2", "fig5", "simulate", "calibrate", "serve", "llm", "bench-llm"] {
        assert!(stdout.contains(cmd), "help missing {cmd}");
    }
}

fn decoder_fixture() -> String {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/decoder_block.mlir")
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn table1_prints_comparison() {
    let (stdout, _, ok) = run(&["table1"]);
    assert!(ok);
    assert!(stdout.contains("SCALE-Sim TPU (this work)"));
    assert!(stdout.contains("StableHLO"));
    assert!(stdout.contains("true"));
}

#[test]
fn simulate_single_gemm_with_extensions() {
    let trace = std::env::temp_dir().join("scalesim_cli_trace.csv");
    let (stdout, _, ok) = run(&[
        "simulate",
        "--m",
        "256",
        "--k",
        "256",
        "--n",
        "256",
        "--energy",
        "--sparsity",
        "0.5",
        "--trace",
        trace.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("GEMM 256x256x256"));
    assert!(stdout.contains("regime: medium"));
    assert!(stdout.contains("energy:"));
    assert!(stdout.contains("speedup"));
    let csv = std::fs::read_to_string(&trace).unwrap();
    assert!(csv.starts_with("fold,start_cycle"));
    std::fs::remove_file(&trace).ok();
}

#[test]
fn simulate_topology_csv() {
    let (stdout, _, ok) = run(&["simulate", "--topology", "topologies/bert_base_layer.csv"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("ffn_up"));
    assert!(stdout.contains("total:"));
}

#[test]
fn fig2_runs_and_writes_csv() {
    let s = Scratch::new("fig2");
    let (stdout, _, ok) = run(&["fig2", "--reps", "1", "--out", &s.path("out")]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("wrote"));
    assert!(std::fs::read_to_string(s.0.join("out/fig2.csv")).is_ok());
}

#[test]
fn fig3_runs_and_writes_csv() {
    let s = Scratch::new("fig3");
    let (stdout, _, ok) = run(&["fig3", "--reps", "1", "--out", &s.path("out")]);
    assert!(ok, "{stdout}");
    assert!(std::fs::read_to_string(s.0.join("out/fig3.csv")).is_ok());
}

#[test]
fn fig4_runs_and_writes_csv() {
    let s = Scratch::new("fig4");
    let (stdout, _, ok) = run(&["fig4", "--reps", "1", "--out", &s.path("out")]);
    assert!(ok, "{stdout}");
    assert!(std::fs::read_to_string(s.0.join("out/fig4.csv")).is_ok());
}

#[test]
fn fig5_runs_and_writes_csv() {
    let s = Scratch::new("fig5");
    let (stdout, _, ok) = run(&[
        "fig5", "--reps", "1", "--shapes", "60", "--out", &s.path("out"),
    ]);
    assert!(ok, "{stdout}");
    assert!(std::fs::read_to_string(s.0.join("out/fig5.csv")).is_ok());
}

#[test]
fn calibrate_saves_assets() {
    let s = Scratch::new("calibrate");
    let assets = s.path("assets");
    let (stdout, _, ok) = run(&["calibrate", "--shapes", "30", "--reps", "1", "--assets", &assets]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("saved calibration"));
    assert!(std::fs::read_to_string(s.0.join("assets/calibration.json")).is_ok());
    assert!(std::fs::read_to_string(s.0.join("assets/config.json")).is_ok());
}

#[test]
fn simulate_module_single_and_distributed() {
    let s = Scratch::new("module_dist");
    let assets = s.path("assets");
    let module = bert_fixture();

    // Single-chip estimate (builds the assets once).
    let (single_out, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
    ]);
    assert!(ok, "{single_out}");
    assert!(single_out.contains("module @bert_layer"));
    assert!(single_out.contains("model coverage"));

    // The acceptance path: 8 chips at 100 GB/s prints per-chip busy
    // time, collective time and parallel efficiency.
    let (dist_out, _, ok) = run(&[
        "simulate", "--module", &module, "--chips", "8", "--ici-gbps", "100", "--shapes", "30",
        "--reps", "1", "--assets", &assets,
    ]);
    assert!(ok, "{dist_out}");
    assert!(dist_out.contains("slice: 8 chips"));
    assert!(dist_out.contains("per-chip busy time"));
    assert!(dist_out.contains("collective"));
    assert!(dist_out.contains("parallel efficiency"));

    // And a 1-chip slice reports 100% efficiency (identity with the
    // single-chip estimate is asserted bit-for-bit at the library level).
    let (one_out, _, ok) = run(&[
        "simulate", "--module", &module, "--chips", "1", "--shapes", "30", "--reps", "1",
        "--assets", &assets,
    ]);
    assert!(ok, "{one_out}");
    assert!(one_out.contains("parallel efficiency 100.0%"), "{one_out}");
}

#[test]
fn simulate_module_reports_schedule_and_engines() {
    let s = Scratch::new("module_sched");
    let assets = s.path("assets");
    let module = bert_fixture();
    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--timeline",
    ]);
    assert!(ok, "{stdout}");
    for needle in ["unfused", "fused", "scheduled", "critical path", "engine utilization", "mxu"] {
        assert!(stdout.contains(needle), "missing '{needle}' in: {stdout}");
    }
    assert!(stdout.contains("timeline @bert_layer"), "{stdout}");
}

#[test]
fn simulate_module_json_emits_full_table() {
    use scalesim_tpu::util::json::Json;

    let s = Scratch::new("module_json");
    let assets = s.path("assets");
    let module = bert_fixture();

    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--json",
    ]);
    assert!(ok, "{stdout}");
    let j = Json::parse(stdout.trim()).expect("one JSON object on stdout");
    assert_eq!(j.req_str("module").unwrap(), "bert_layer");
    let unfused = j.req_f64("unfused_us").unwrap();
    let scheduled = j.req_f64("scheduled_us").unwrap();
    let critical = j.req_f64("critical_path_us").unwrap();
    assert!(critical <= scheduled && scheduled <= unfused, "{j:?}");
    let ops = j.req_arr("ops").unwrap();
    assert_eq!(ops.len(), 33);
    let first = &ops[0];
    assert_eq!(first.req_str("engine").unwrap(), "mxu");
    assert!(first.req_f64("end_us").unwrap() >= first.req_f64("start_us").unwrap());
    assert!(j.get("engines").unwrap().get("vpu").is_some());

    // Distributed --json carries the slice and per-op timeline.
    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--chips", "4", "--shapes", "30", "--reps", "1",
        "--assets", &assets, "--json",
    ]);
    assert!(ok, "{stdout}");
    let j = Json::parse(stdout.trim()).expect("one JSON object on stdout");
    assert_eq!(j.req_f64("chips").unwrap(), 4.0);
    assert!(j.req_f64("critical_path_us").unwrap() <= j.req_f64("total_us").unwrap());
    assert_eq!(j.req_arr("ops").unwrap().len(), 33);
}

#[test]
fn simulate_module_memory_reports_residency_and_roofline() {
    let s = Scratch::new("module_memory");
    let assets = s.path("assets");
    let module = bert_fixture();

    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--memory",
    ]);
    assert!(ok, "{stdout}");
    for needle in [
        "memory-aware:",
        "serialized bound",
        "dma busy",
        "residency",
        "cold fetches",
        "roofline:",
    ] {
        assert!(stdout.contains(needle), "missing '{needle}' in: {stdout}");
    }

    // The distributed path threads the same model through the slice.
    let (dist_out, _, ok) = run(&[
        "simulate", "--module", &module, "--chips", "4", "--shapes", "30", "--reps", "1",
        "--assets", &assets, "--memory",
    ]);
    assert!(ok, "{dist_out}");
    assert!(dist_out.contains("dma us"), "{dist_out}");
    assert!(dist_out.contains("per-chip dma busy"), "{dist_out}");
}

#[test]
fn simulate_module_memory_json_schema() {
    use scalesim_tpu::util::json::Json;

    let s = Scratch::new("module_memory_json");
    let assets = s.path("assets");
    let module = bert_fixture();

    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--memory", "--json",
    ]);
    assert!(ok, "{stdout}");
    let j = Json::parse(stdout.trim()).expect("one JSON object on stdout");
    let scheduled = j.req_f64("scheduled_us").unwrap();
    let memory_us = j.req_f64("memory_us").unwrap();
    assert!(
        memory_us >= scheduled,
        "memory-aware {memory_us} beat compute-only {scheduled}"
    );
    let mem = j.get("memory").expect("memory block");
    assert!(mem.req_f64("serialized_bound_us").unwrap() >= memory_us);
    assert!(mem.req_f64("cold_bytes").unwrap() > 0.0);
    let roofline = j.get("roofline").expect("roofline block");
    assert!(roofline.req_str("verdict").is_ok());
    assert_eq!(roofline.req_arr("ops").unwrap().len(), 33);
    // Every op row gains the dma/residency fields.
    let ops = j.req_arr("ops").unwrap();
    assert_eq!(ops.len(), 33);
    for op in ops {
        assert!(op.req_f64("dma_in_us").unwrap() >= 0.0);
        assert!(op.req_f64("dma_out_us").unwrap() >= 0.0);
        assert!(op.get("resident").is_some(), "{op:?}");
        let bound = op.req_str("bound").unwrap();
        assert!(["compute", "bandwidth", "free"].contains(&bound), "{bound}");
    }
    // Without --memory the schema is unchanged: no memory keys.
    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--json",
    ]);
    assert!(ok, "{stdout}");
    let j = Json::parse(stdout.trim()).unwrap();
    assert!(j.get("memory_us").is_none());
    assert!(j.req_arr("ops").unwrap()[0].get("dma_in_us").is_none());
}

#[test]
fn simulate_gemm_with_chips() {
    let (stdout, _, ok) = run(&[
        "simulate", "--m", "4096", "--k", "1024", "--n", "1024", "--chips", "4", "--ici-gbps",
        "100",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("slice: 4 chips"));
    assert!(stdout.contains("parallel efficiency"));
}

#[test]
fn serve_answers_jsonl_from_input_file() {
    let s = Scratch::new("serve");
    let input = s.path("requests.jsonl");
    std::fs::write(
        &input,
        concat!(
            "{\"type\":\"gemm\",\"m\":256,\"k\":256,\"n\":256}\n",
            "{\"type\":\"gemm\",\"m\":256,\"k\":256,\"n\":1024,\"chips\":4,\"ici_gbps\":50}\n",
            "{\"type\":\"stats\"}\n"
        ),
    )
    .unwrap();
    let (stdout, stderr, ok) = run(&[
        "serve", "--input", &input, "--shapes", "30", "--reps", "1", "--assets",
        &s.path("assets"), "--workers", "2",
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    let lines: Vec<&str> = stdout.lines().filter(|l| l.starts_with('{')).collect();
    assert_eq!(lines.len(), 3, "{stdout}");
    assert!(lines[0].contains("\"ok\":true"));
    assert!(lines[1].contains("\"chips\":4"));
    assert!(lines[2].contains("cache_hits"));
    assert!(stderr.contains("serve:"), "missing shutdown summary: {stderr}");
}

#[test]
fn device_tpu_v4_flag_is_bit_identical_to_default_in_every_mode() {
    // The golden satellite: `--device tpu-v4` must equal the default
    // (pre-refactor) output byte for byte across unfused/scheduled,
    // memory-aware and distributed modes.
    let s = Scratch::new("device_golden");
    let assets = s.path("assets");
    let module = bert_fixture();
    // Build the assets once so every compared run loads the same set
    // (and stdout carries no one-time build chatter).
    let (_, _, ok) = run(&["calibrate", "--shapes", "30", "--reps", "1", "--assets", &assets]);
    assert!(ok);
    let modes: [Vec<&str>; 4] = [
        Vec::new(),
        vec!["--memory"],
        vec!["--chips", "4"],
        vec!["--chips", "4", "--memory"],
    ];
    for extra in &modes {
        let mut base_args = vec!["simulate", "--module", &module, "--assets", &assets, "--json"];
        base_args.extend(extra.iter().copied());
        let (default_out, _, ok1) = run(&base_args);
        let mut dev_args = base_args.clone();
        dev_args.extend(["--device", "tpu-v4"]);
        let (device_out, _, ok2) = run(&dev_args);
        assert!(ok1 && ok2, "mode {extra:?} failed");
        assert!(!default_out.trim().is_empty());
        assert_eq!(default_out, device_out, "mode {extra:?} diverged");
    }
}

#[test]
fn device_flag_selects_a_different_self_consistent_scenario() {
    use scalesim_tpu::util::json::Json;

    let s = Scratch::new("device_v5e");
    let assets = s.path("assets");
    let module = bert_fixture();
    let (v4_out, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--memory", "--chips", "4", "--json",
    ]);
    assert!(ok, "{v4_out}");
    let (v5e_out, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--memory", "--chips", "4", "--device", "tpu-v5e", "--json",
    ]);
    assert!(ok, "{v5e_out}");
    assert_ne!(v4_out, v5e_out, "tpu-v5e reproduced the tpu-v4 report");
    let j = Json::parse(v5e_out.trim()).unwrap();
    assert_eq!(j.req_str("device").unwrap(), "tpu-v5e");
    assert_eq!(j.req_f64("chips").unwrap(), 4.0);
    // v5e defaults to a torus; its per-chip report stays self-consistent.
    assert_eq!(j.req_str("ici_topology").unwrap(), "2x2 torus");
    assert!(j.req_f64("critical_path_us").unwrap() <= j.req_f64("total_us").unwrap());
    let eff = j.req_f64("parallel_efficiency").unwrap();
    assert!(eff > 0.0 && eff <= 1.0, "efficiency {eff}");
}

#[test]
fn device_overrides_apply_on_top_of_the_spec() {
    use scalesim_tpu::util::json::Json;

    let s = Scratch::new("device_override");
    let assets = s.path("assets");
    let module = bert_fixture();
    // No override flags: the v5e spec supplies VMEM (16 MiB) and HBM
    // bandwidth (819 GB/s = 819e3 bytes/us).
    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--device", "tpu-v5e", "--memory", "--json",
    ]);
    assert!(ok, "{stdout}");
    let mem = |out: &str, key: &str| -> f64 {
        Json::parse(out.trim())
            .unwrap()
            .get("memory")
            .expect("memory block")
            .req_f64(key)
            .unwrap()
    };
    assert_eq!(mem(&stdout, "buffer_bytes"), 16.0 * 1024.0 * 1024.0);
    assert_eq!(mem(&stdout, "hbm_bytes_per_us"), 819e3);
    // Explicit flags override the selected spec.
    let (stdout, _, ok) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--device", "tpu-v5e", "--memory", "--vmem-mb", "1", "--hbm-gbps", "500", "--json",
    ]);
    assert!(ok, "{stdout}");
    assert_eq!(mem(&stdout, "buffer_bytes"), 1024.0 * 1024.0);
    assert_eq!(mem(&stdout, "hbm_bytes_per_us"), 500e3);
    // Same precedence on the ICI side: the spec's 50 GB/s link yields to
    // an explicit --ici-gbps.
    let (spec_ici, _, ok1) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--device", "tpu-v5e", "--chips", "4", "--json",
    ]);
    let (flag_ici, _, ok2) = run(&[
        "simulate", "--module", &module, "--shapes", "30", "--reps", "1", "--assets", &assets,
        "--device", "tpu-v5e", "--chips", "4", "--ici-gbps", "400", "--json",
    ]);
    assert!(ok1 && ok2);
    let gbps = |out: &str| Json::parse(out.trim()).unwrap().req_f64("ici_gbps").unwrap();
    assert_eq!(gbps(&spec_ici), 50.0);
    assert_eq!(gbps(&flag_ici), 400.0);
}

#[test]
fn unknown_device_fails_cleanly() {
    let (_, stderr, ok) = run(&["simulate", "--m", "8", "--k", "8", "--n", "8", "--device", "tpu-v9"]);
    assert!(!ok);
    assert!(stderr.contains("unknown device"), "{stderr}");
    assert!(stderr.contains("tpu-v5e"), "should list presets: {stderr}");
    // Conflicting device selectors are an error, not a silent pick.
    let (_, stderr, ok) = run(&[
        "simulate", "--m", "8", "--k", "8", "--n", "8", "--device", "tpu-v4", "--device-file",
        "x.toml",
    ]);
    assert!(!ok);
    assert!(stderr.contains("mutually exclusive"), "{stderr}");
}

#[test]
fn devices_lists_presets_and_checks_the_checked_in_files() {
    let (stdout, _, ok) = run(&["devices"]);
    assert!(ok);
    for name in ["tpu-v4", "tpu-v5e", "tpu-v5p", "generic-256x256"] {
        assert!(stdout.contains(name), "devices listing missing {name}");
    }
    assert!(stdout.contains("HBM GB/s"));
    // Round-trip every checked-in device file (the CI smoke).
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("devices");
    let (stdout, stderr, ok) = run(&["devices", "--check", "--dir", dir.to_str().unwrap()]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("4 device files OK"), "{stdout}");
    // An explicit --dir that does not exist is an error, never a silent
    // fallback to the local devices/ directory.
    let (_, stderr, ok) = run(&["devices", "--check", "--dir", "/no/such/devices-dir"]);
    assert!(!ok);
    assert!(stderr.contains("not found"), "{stderr}");
}

#[test]
fn devices_check_rejects_a_drifted_preset_file() {
    let s = Scratch::new("devices_drift");
    // A file that names a preset but changes a parameter must fail the
    // drift check.
    std::fs::write(
        s.0.join("tpu-v4.toml"),
        "name = \"tpu-v4\"\n[memory]\nhbm_gbps = 999.0\n",
    )
    .unwrap();
    let (_, stderr, ok) = run(&["devices", "--check", "--dir", s.0.to_str().unwrap()]);
    assert!(!ok);
    assert!(stderr.contains("drifted"), "{stderr}");
}

#[test]
fn compare_runs_one_module_against_several_devices() {
    use scalesim_tpu::util::json::Json;

    let s = Scratch::new("compare");
    let assets = s.path("assets");
    let module = bert_fixture();
    let (stdout, stderr, ok) = run(&[
        "compare", "--module", &module, "--devices", "tpu-v4,tpu-v5e,generic-256x256",
        "--chips", "4", "--shapes", "30", "--reps", "1", "--assets", &assets,
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    for name in ["tpu-v4", "tpu-v5e", "generic-256x256"] {
        assert!(stdout.contains(name), "comparison missing {name}");
    }
    assert!(stdout.contains("memory us"));
    assert!(stdout.contains("speedup"));

    // JSON mode: one object, one row per device, invariants intact.
    let (stdout, _, ok) = run(&[
        "compare", "--module", &module, "--devices", "tpu-v4,tpu-v5e", "--shapes", "30",
        "--reps", "1", "--assets", &assets, "--json",
    ]);
    assert!(ok, "{stdout}");
    let j = Json::parse(stdout.trim()).expect("one JSON object");
    assert_eq!(j.req_str("module").unwrap(), "bert_layer");
    let rows = j.req_arr("devices").unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        let scheduled = row.req_f64("scheduled_us").unwrap();
        let memory = row.req_f64("memory_us").unwrap();
        let bound = row.req_f64("serialized_bound_us").unwrap();
        assert!(
            scheduled <= memory && memory <= bound,
            "invariant broke for {row:?}"
        );
    }
    // The two devices disagree on at least the memory-aware total.
    assert_ne!(
        rows[0].req_f64("memory_us").unwrap().to_bits(),
        rows[1].req_f64("memory_us").unwrap().to_bits()
    );
}

#[test]
fn sweep_golden_csv_matches_the_checked_in_fixture() {
    // The golden satellite: the tpu-v4 small-grid sweep is a pure
    // function of the device spec and grid, so its CSV must regenerate
    // byte-identically. The fixture is produced by the independent
    // Python replica tests/fixtures/gen_sweep_golden.py — regenerate
    // both together on an intentional model change.
    let (stdout, stderr, ok) = run(&["sweep", "--device", "tpu-v4", "--grid", "small", "--csv"]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert_eq!(
        stdout,
        include_str!("fixtures/sweep_small_tpu-v4.csv"),
        "sweep CSV drifted from the golden fixture"
    );
}

#[test]
fn sweep_json_reports_every_class_warm_identical() {
    use scalesim_tpu::util::json::Json;

    let (stdout, _, ok) = run(&["sweep", "--device", "tpu-v5p", "--grid", "small", "--json"]);
    assert!(ok, "{stdout}");
    let j = Json::parse(stdout.trim()).expect("one JSON object on stdout");
    assert_eq!(j.req_str("device").unwrap(), "tpu-v5p");
    assert_eq!(j.req_str("grid").unwrap(), "small");
    assert!(j.req_f64("total_cases").unwrap() > 0.0);
    let classes = j.req_arr("classes").unwrap();
    assert_eq!(classes.len(), 7, "expected every op class by default");
    for c in classes {
        let name = c.req_str("class").unwrap();
        assert_eq!(
            c.get("warm_identical").and_then(Json::as_bool),
            Some(true),
            "{name}: warm pass diverged from cold"
        );
        let warm = c.get("warm").expect("warm pass stats");
        assert_eq!(warm.req_f64("misses").unwrap(), 0.0, "{name}: warm misses");
    }

    // --ops restricts the sweep to the named classes, in order.
    let (stdout, _, ok) = run(&[
        "sweep", "--device", "tpu-v4", "--grid", "small", "--ops", "conv,matmul", "--json",
    ]);
    assert!(ok, "{stdout}");
    let j = Json::parse(stdout.trim()).unwrap();
    let classes = j.req_arr("classes").unwrap();
    assert_eq!(classes.len(), 2);
    assert_eq!(classes[0].req_str("class").unwrap(), "conv");
    assert_eq!(classes[1].req_str("class").unwrap(), "matmul");
}

#[test]
fn sweep_default_render_is_the_summary_table() {
    let (stdout, _, ok) = run(&["sweep", "--device", "tpu-v4", "--grid", "small"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("sweep: device=tpu-v4 grid=small"), "{stdout}");
    for needle in ["matmul", "data-movement", "bit-identical", "warm est/s"] {
        assert!(stdout.contains(needle), "missing '{needle}' in: {stdout}");
    }
}

#[test]
fn sweep_rejects_bad_flags_cleanly() {
    // Unknown op class: named, and the known ones listed.
    let (_, stderr, ok) = run(&["sweep", "--device", "tpu-v4", "--ops", "matmul,frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown op class 'frobnicate'"), "{stderr}");
    assert!(stderr.contains("pooling"), "should list known classes: {stderr}");
    // An --ops list that selects nothing is an error, not an empty sweep.
    let (_, stderr, ok) = run(&["sweep", "--device", "tpu-v4", "--ops", ", ,"]);
    assert!(!ok);
    assert!(stderr.contains("selected no op classes"), "{stderr}");
    // Malformed --grid.
    let (_, stderr, ok) = run(&["sweep", "--device", "tpu-v4", "--grid", "enormous"]);
    assert!(!ok);
    assert!(stderr.contains("unknown grid 'enormous'"), "{stderr}");
    // Conflicting device selectors, same rule as simulate.
    let (_, stderr, ok) = run(&["sweep", "--device", "tpu-v4", "--device-file", "x.toml"]);
    assert!(!ok);
    assert!(stderr.contains("mutually exclusive"), "{stderr}");
}

#[test]
fn llm_json_reports_a_consistent_serving_run() {
    use scalesim_tpu::util::json::Json;

    let module = decoder_fixture();
    let (stdout, stderr, ok) = run(&["llm", "--module", &module, "--device", "tpu-v4", "--json"]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    let j = Json::parse(stdout.trim()).expect("one JSON object on stdout");
    assert_eq!(j.req_str("module").unwrap(), "decoder_block");
    assert_eq!(j.req_str("device").unwrap(), "tpu-v4");
    assert_eq!(j.req_f64("requests").unwrap(), 16.0);
    let tps = j.req_f64("tokens_per_sec").unwrap();
    assert!(tps > 0.0);
    assert!(tps <= j.req_f64("roofline_tokens_per_sec").unwrap());
    assert!(j.req_f64("ttft_p50_us").unwrap() <= j.req_f64("latency_p50_us").unwrap());
    assert_eq!(j.req_f64("kv_evictions").unwrap(), 0.0);
    assert_eq!(j.req_f64("kv_bytes_per_token").unwrap(), 4096.0);
    assert_eq!(j.req_arr("requests_detail").unwrap().len(), 16);

    // Same invocation, same bytes — the CLI is deterministic.
    let (again, _, ok) = run(&["llm", "--module", &module, "--device", "tpu-v4", "--json"]);
    assert!(ok);
    assert_eq!(stdout, again, "llm --json drifted between runs");
}

#[test]
fn llm_phase_csv_matches_the_checked_in_golden() {
    let module = decoder_fixture();
    let (stdout, stderr, ok) = run(&["llm", "--module", &module, "--phase-csv"]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert_eq!(
        stdout,
        include_str!("fixtures/llm_phases.csv"),
        "phase CSV drifted from the golden fixture"
    );
}

#[test]
fn llm_renders_report_and_writes_trace() {
    let s = Scratch::new("llm_trace");
    let trace = s.path("llm.trace.json");
    let module = decoder_fixture();
    let (stdout, stderr, ok) = run(&[
        "llm", "--module", &module, "--device", "tpu-v5e", "--requests", "4", "--trace-out",
        &trace,
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    for needle in ["llm serve:", "phases:", "throughput:", "ttft:", "kv:"] {
        assert!(stdout.contains(needle), "missing '{needle}' in: {stdout}");
    }
    let json = std::fs::read_to_string(s.0.join("llm.trace.json")).unwrap();
    assert!(json.contains("\"llm-serve\""), "{json}");
    assert!(json.contains("\"prefill\""), "{json}");
}

#[test]
fn llm_requires_a_module() {
    let (_, stderr, ok) = run(&["llm", "--device", "tpu-v4"]);
    assert!(!ok);
    assert!(stderr.contains("--module"), "{stderr}");
}

#[test]
fn compare_llm_adds_serving_columns() {
    use scalesim_tpu::util::json::Json;

    let s = Scratch::new("compare_llm");
    let assets = s.path("assets");
    let module = decoder_fixture();
    let (stdout, stderr, ok) = run(&[
        "compare", "--module", &module, "--devices", "tpu-v4,tpu-v5e", "--llm", "--shapes",
        "30", "--reps", "1", "--assets", &assets, "--json",
    ]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    let j = Json::parse(stdout.trim()).expect("one JSON object");
    let rows = j.req_arr("devices").unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        assert!(row.req_f64("prefill_us").unwrap() > row.req_f64("decode_step_us").unwrap());
        assert!(row.req_f64("tokens_per_sec").unwrap() > 0.0);
        assert!(row.req_f64("ttft_p50_us").unwrap() > 0.0);
    }
    // The human table grows the llm columns.
    let (table, _, ok) = run(&[
        "compare", "--module", &module, "--devices", "tpu-v4", "--llm", "--shapes", "30",
        "--reps", "1", "--assets", &assets,
    ]);
    assert!(ok, "{table}");
    for needle in ["prefill us", "decode us", "tok/s", "ttft p50 us"] {
        assert!(table.contains(needle), "missing '{needle}' in: {table}");
    }
}

#[test]
fn bench_llm_json_covers_every_preset_and_check_passes() {
    use scalesim_tpu::util::json::Json;

    let (stdout, stderr, ok) = run(&["bench-llm", "--requests", "8", "--json"]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    let j = Json::parse(stdout.trim()).expect("JSON-only stdout");
    assert_eq!(j.req_str("bench").unwrap(), "llm");
    let rows = j.req_arr("devices").unwrap();
    assert_eq!(rows.len(), 4);
    for row in rows {
        assert!(row.req_f64("tokens_per_sec").unwrap() > 0.0, "{row:?}");
    }
    assert!(stderr.contains("bench-llm:"), "summary on stderr: {stderr}");

    // The checked-in BENCH_llm.json is fresh against the current source.
    let (stdout, stderr, ok) = run(&["bench-llm", "--check"]);
    assert!(ok, "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("fresh"), "{stdout}");
}

#[test]
fn unknown_subcommand_fails_cleanly() {
    let (_, stderr, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn bad_dataflow_rejected() {
    let (_, stderr, ok) = run(&["simulate", "--m", "8", "--k", "8", "--n", "8", "--dataflow", "zz"]);
    assert!(!ok);
    assert!(stderr.contains("bad dataflow"));
}
