//! Integration: the distributed (multi-chip) estimator over the
//! checked-in BERT-layer and collectives fixtures — the acceptance path
//! of `scalesim-tpu simulate --module <fixture> --chips N`.

use std::path::Path;
use std::sync::Arc;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::{serve_lines, Estimator};
use scalesim_tpu::distributed::{
    estimate_module_distributed, IciTopology, SliceConfig,
};
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};
use scalesim_tpu::util::json::Json;

fn estimator() -> Estimator {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
}

fn fixture_path(name: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

fn fixture(name: &str) -> ModuleInfo {
    let text = std::fs::read_to_string(fixture_path(name)).unwrap();
    parse_module(&text).unwrap()
}

#[test]
fn bert_layer_one_chip_matches_single_chip_estimate_exactly() {
    let est = estimator();
    let module = fixture("bert_layer.mlir");
    let single = est.estimate_module(&module);
    let one = estimate_module_distributed(&est, &module, &SliceConfig::single_chip());
    assert_eq!(
        one.total_us.to_bits(),
        single.total_us.to_bits(),
        "1-chip slice diverged from the single-chip estimate"
    );
    assert_eq!(one.collective_us, 0.0);
    assert_eq!(one.parallel_efficiency(), 1.0);
    assert_eq!(one.ops.len(), single.ops.len());
}

#[test]
fn bert_layer_scales_across_chips() {
    let est = estimator();
    let module = fixture("bert_layer.mlir");
    let single = est.estimate_module(&module).total_us;

    let mut last = f64::INFINITY;
    for chips in [1usize, 4, 8] {
        let d = estimate_module_distributed(&est, &module, &SliceConfig::ring(chips, 100.0));
        assert!(
            d.total_us <= last,
            "{chips} chips slower than fewer: {} > {last}",
            d.total_us
        );
        let e = d.parallel_efficiency();
        assert!(e > 0.0 && e <= 1.0, "efficiency {e} at {chips} chips");
        last = d.total_us;
    }

    // 8 chips must beat one chip clearly on a layer this parallel, and
    // the sharded FFN-up matmul pays a real all-gather.
    let d8 = estimate_module_distributed(&est, &module, &SliceConfig::ring(8, 100.0));
    assert!(d8.total_us < single / 2.0, "{} vs {single}", d8.total_us);
    assert!(d8.collective_us > 0.0, "sharded FFN paid no all-gather");
}

#[test]
fn collectives_fixture_costs_ici_time_and_respects_bandwidth() {
    let est = estimator();
    let module = fixture("collectives.mlir");

    let slow = estimate_module_distributed(&est, &module, &SliceConfig::ring(4, 10.0));
    let fast = estimate_module_distributed(&est, &module, &SliceConfig::ring(4, 400.0));
    assert!(slow.collective_us > fast.collective_us);
    assert!(slow.total_us > fast.total_us);

    // A 2x2 torus finishes the same collectives no slower than the ring.
    let torus = estimate_module_distributed(
        &est,
        &module,
        &SliceConfig {
            chips: 4,
            topology: IciTopology::Torus2D { x: 2, y: 2 },
            link_gbps: 10.0,
            hop_latency_us: 1.0,
        },
    );
    assert!(torus.collective_us <= slow.collective_us);

    // All four collective kinds got a nonzero ICI cost.
    let ici_ops: Vec<_> = slow
        .ops
        .iter()
        .filter(|o| o.collective_us > 0.0 && o.compute_us == 0.0)
        .collect();
    assert_eq!(ici_ops.len(), 4, "{ici_ops:?}");
}

#[test]
fn serve_answers_distributed_module_requests() {
    let est = Arc::new(estimator());
    let path = fixture_path("bert_layer.mlir");
    let single_line = format!(r#"{{"type":"module","path":"{}"}}"#, path.display());
    let dist_line = format!(
        r#"{{"type":"module","path":"{}","chips":8,"ici_gbps":100}}"#,
        path.display()
    );
    let responses = serve_lines(est, &[single_line, dist_line], 2);

    let single = Json::parse(&responses[0]).unwrap();
    assert_eq!(single.get("ok"), Some(&Json::Bool(true)), "{single:?}");
    let dist = Json::parse(&responses[1]).unwrap();
    assert_eq!(dist.get("ok"), Some(&Json::Bool(true)), "{dist:?}");
    assert_eq!(dist.req_f64("chips").unwrap(), 8.0);
    assert!(dist.req_f64("total_us").unwrap() < single.req_f64("total_us").unwrap());
    assert!(dist.req_f64("collective_us").unwrap() > 0.0);
    let eff = dist.req_f64("parallel_efficiency").unwrap();
    assert!(eff > 0.0 && eff <= 1.0);
    // The distributed response reports the baseline it was compared to.
    assert_eq!(
        dist.req_f64("single_chip_us").unwrap().to_bits(),
        single.req_f64("total_us").unwrap().to_bits()
    );
}
