//! Property-based tests over coordinator/simulator invariants.
//!
//! The offline registry has no proptest, so these are randomized-input
//! property checks driven by the crate's own deterministic PRNG: each
//! property is evaluated over a few hundred random cases with a fixed
//! seed (failures reproduce exactly).

use scalesim_tpu::calibrate::{fit_regime_calibration, Regime};
use scalesim_tpu::coordinator::{parallel_map, Estimator};
use scalesim_tpu::distributed::{estimate_module_distributed, IciTopology, SliceConfig};
use scalesim_tpu::frontend::types::{DType, TensorType};
use scalesim_tpu::frontend::{classify, parse_module, EwKind, OpClass};
use scalesim_tpu::learned::featurize;
use scalesim_tpu::scalesim::{
    simulate_gemm, simulate_partitioned, Dataflow, GemmShape, PartitionAxis, ScaleConfig,
};
use scalesim_tpu::tpu::vpu;
use scalesim_tpu::util::prng::Prng;

fn random_gemm(prng: &mut Prng, max: usize) -> GemmShape {
    GemmShape::new(
        prng.int_range(1, max as i64) as usize,
        prng.int_range(1, max as i64) as usize,
        prng.int_range(1, max as i64) as usize,
    )
}

#[test]
fn prop_simulation_invariants_hold_for_random_shapes() {
    let mut prng = Prng::new(2024);
    for df in [
        Dataflow::OutputStationary,
        Dataflow::WeightStationary,
        Dataflow::InputStationary,
    ] {
        let mut config = ScaleConfig::tpu_v4();
        config.dataflow = df;
        for _ in 0..300 {
            let g = random_gemm(&mut prng, 3000);
            let r = simulate_gemm(&config, g);
            // Invariants: cycle decomposition, bounded ratios, work done.
            assert_eq!(
                r.total_cycles(),
                r.compute_cycles + r.stall_cycles + r.initial_fill_cycles,
                "{df} {g}"
            );
            assert!(r.utilisation > 0.0 && r.utilisation <= 1.0, "{df} {g}");
            assert!(
                r.mapping_efficiency > 0.0 && r.mapping_efficiency <= 1.0 + 1e-12,
                "{df} {g}"
            );
            // Enough cycles to issue every MAC at peak rate.
            let min_cycles = (g.macs() as f64 / config.peak_macs_per_cycle()).ceil() as u64;
            assert!(r.total_cycles() >= min_cycles, "{df} {g}");
            // DRAM reads at least one copy of each operand.
            assert!(r.ifmap_dram_reads >= g.a_words(), "{df} {g}");
            assert!(r.filter_dram_reads >= g.b_words(), "{df} {g}");
            assert!(r.ofmap_dram_writes >= g.c_words(), "{df} {g}");
        }
    }
}

#[test]
fn prop_partitioning_conserves_work_and_never_slows_down_makespan_much() {
    let mut prng = Prng::new(7);
    let config = ScaleConfig::tpu_v4();
    for _ in 0..150 {
        let g = random_gemm(&mut prng, 4096);
        let cores = 1 + prng.index(8);
        let axis = if prng.index(2) == 0 {
            PartitionAxis::M
        } else {
            PartitionAxis::N
        };
        let p = simulate_partitioned(&config, g, cores, axis);
        let shard_macs: u64 = p.shards.iter().map(|s| s.gemm.macs()).sum();
        assert_eq!(shard_macs, g.macs(), "{g} cores={cores} {axis}");
        // Makespan never exceeds the single-core run (shards are subsets).
        let single = simulate_gemm(&config, g);
        assert!(
            p.makespan_cycles <= single.total_cycles(),
            "{g} cores={cores} {axis}"
        );
    }
}

#[test]
fn prop_regime_routing_total_and_exclusive() {
    let mut prng = Prng::new(99);
    for _ in 0..1000 {
        let g = random_gemm(&mut prng, 8192);
        let regime = Regime::of_gemm(&g);
        // Exactly one regime claims each shape.
        let claims = Regime::ALL
            .iter()
            .filter(|r| Regime::of_gemm(&g) == **r)
            .count();
        assert_eq!(claims, 1);
        // Routing is by max dim.
        let maxdim = g.m.max(g.k).max(g.n);
        match regime {
            Regime::Small => assert!(maxdim <= 128),
            Regime::Medium => assert!(maxdim > 128 && maxdim <= 1024),
            Regime::Large => assert!(maxdim > 1024),
        }
    }
}

#[test]
fn prop_classifier_routes_every_generated_dot_general() {
    // Generate random matmul modules textually and assert the classifier
    // always produces the right GEMM (parser + classifier round-trip).
    let mut prng = Prng::new(5);
    for _ in 0..120 {
        let (m, k, n) = (
            prng.int_range(1, 2048) as usize,
            prng.int_range(1, 2048) as usize,
            prng.int_range(1, 2048) as usize,
        );
        let text = format!(
            r#"module {{ func.func @main(%a: tensor<{m}x{k}xf32>, %b: tensor<{k}x{n}xf32>) -> tensor<{m}x{n}xf32> {{
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<{m}x{k}xf32>, tensor<{k}x{n}xf32>) -> tensor<{m}x{n}xf32>
  return %0 : tensor<{m}x{n}xf32>
}} }}"#
        );
        let module = parse_module(&text).unwrap();
        match classify(&module.entry().unwrap().ops[0]) {
            OpClass::SystolicGemm { gemm, count } => {
                assert_eq!(gemm, GemmShape::new(m, k, n));
                assert_eq!(count, 1);
            }
            other => panic!("expected gemm, got {other:?}"),
        }
    }
}

#[test]
fn prop_vpu_latency_monotone_and_featurize_total() {
    let mut prng = Prng::new(31);
    let params = scalesim_tpu::tpu::VpuParams::default();
    for _ in 0..500 {
        let rank = 1 + prng.index(3);
        let dims: Vec<usize> = (0..rank)
            .map(|_| prng.int_range(1, 512) as usize)
            .collect();
        // Doubling the leading dim cannot reduce latency.
        let mut bigger = dims.clone();
        bigger[0] *= 2;
        let t1 = vpu::latency_us(&params, EwKind::Add, &dims);
        let t2 = vpu::latency_us(&params, EwKind::Add, &bigger);
        assert!(
            t2 > t1 * 0.96,
            "latency dropped: {dims:?} {t1} -> {bigger:?} {t2}"
        );
        // Features are finite and the element count matches.
        let f = featurize(&dims);
        assert!(f.iter().all(|v| v.is_finite()));
        let elems: u64 = dims.iter().map(|&d| d as u64).product();
        assert_eq!(f[0] as u64, elems);
    }
}

fn calibrated_estimator() -> Estimator {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
}

/// A random matmul+epilogue module, optionally with an all_reduce of the
/// GEMM output (gradient-sync style).
fn random_module_text(prng: &mut Prng, with_collective: bool) -> String {
    let m = 8 * prng.int_range(1, 256) as usize;
    let k = 8 * prng.int_range(1, 256) as usize;
    let n = 8 * prng.int_range(1, 256) as usize;
    let collective = if with_collective {
        format!(
            r#"    %2 = "stablehlo.all_reduce"(%1) ({{
    ^bb0(%a: tensor<f32>, %b: tensor<f32>):
      %s = stablehlo.add %a, %b : tensor<f32>
      stablehlo.return %s : tensor<f32>
    }}) {{replica_groups = dense<[[0, 1]]> : tensor<1x2xi64>}} : (tensor<{m}x{n}xf32>) -> tensor<{m}x{n}xf32>
    return %2 : tensor<{m}x{n}xf32>"#
        )
    } else {
        format!("    return %1 : tensor<{m}x{n}xf32>")
    };
    format!(
        r#"module @rand {{
  func.func @main(%a: tensor<{m}x{k}xf32>, %b: tensor<{k}x{n}xf32>) -> tensor<{m}x{n}xf32> {{
    %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<{m}x{k}xf32>, tensor<{k}x{n}xf32>) -> tensor<{m}x{n}xf32>
    %1 = stablehlo.add %0, %0 : tensor<{m}x{n}xf32>
{collective}
  }}
}}"#
    )
}

#[test]
fn prop_one_chip_slice_is_bit_identical_to_single_chip() {
    let mut prng = Prng::new(411);
    let est = calibrated_estimator();
    for i in 0..40 {
        let module = parse_module(&random_module_text(&mut prng, i % 2 == 0)).unwrap();
        let single = est.estimate_module(&module);
        let one = estimate_module_distributed(&est, &module, &SliceConfig::single_chip());
        assert_eq!(
            one.total_us.to_bits(),
            single.total_us.to_bits(),
            "1-chip slice diverged on case {i}"
        );
        assert_eq!(one.collective_us, 0.0);
    }
}

#[test]
fn prop_latency_monotone_in_link_bandwidth() {
    let mut prng = Prng::new(613);
    let est = calibrated_estimator();
    for _ in 0..25 {
        let module = parse_module(&random_module_text(&mut prng, true)).unwrap();
        let chips = 2 + prng.index(7);
        let mut last = f64::INFINITY;
        for gbps in [2.0, 10.0, 50.0, 250.0, 1000.0] {
            let d = estimate_module_distributed(&est, &module, &SliceConfig::ring(chips, gbps));
            assert!(
                d.total_us <= last,
                "latency rose with bandwidth: chips={chips} gbps={gbps}"
            );
            last = d.total_us;
        }
    }
}

#[test]
fn prop_parallel_efficiency_in_unit_interval() {
    let mut prng = Prng::new(827);
    let est = calibrated_estimator();
    for i in 0..40 {
        let module = parse_module(&random_module_text(&mut prng, i % 3 == 0)).unwrap();
        let chips = 1 + prng.index(8);
        let slice = if prng.index(2) == 0 {
            SliceConfig::ring(chips, 5.0 + 200.0 * prng.index(4) as f64)
        } else {
            SliceConfig {
                chips,
                topology: IciTopology::torus(chips),
                link_gbps: 50.0,
                hop_latency_us: 0.5,
            }
        };
        let d = estimate_module_distributed(&est, &module, &slice);
        let e = d.parallel_efficiency();
        assert!(
            e > 0.0 && e <= 1.0,
            "efficiency {e} out of (0,1]: chips={chips} case={i}"
        );
    }
}

#[test]
fn prop_parallel_map_equals_serial_for_random_workloads() {
    let mut prng = Prng::new(63);
    for _ in 0..20 {
        let n = prng.index(200);
        let items: Vec<u64> = (0..n).map(|_| prng.next_u64() % 1000).collect();
        let serial: Vec<u64> = items.iter().map(|&x| x * 3 + 1).collect();
        for workers in [1, 2, 7, 16] {
            let par = parallel_map(&items, workers, |&x| x * 3 + 1);
            assert_eq!(par, serial);
        }
    }
}

#[test]
fn prop_tensor_type_roundtrip() {
    let mut prng = Prng::new(17);
    for _ in 0..300 {
        let rank = prng.index(5);
        let dims: Vec<usize> = (0..rank)
            .map(|_| prng.int_range(1, 10_000) as usize)
            .collect();
        let t = TensorType::new(dims, DType::Bf16);
        let s = format!("{t}");
        let t2 = TensorType::parse(&s).unwrap();
        assert_eq!(t, t2);
    }
}
