//! Integration: the unified DeviceSpec layer.
//!
//! Covers the refactor's two contracts:
//!
//! * **Golden bit-identity** — the `tpu-v4` preset reproduces the
//!   pre-refactor hard-coded behavior bit for bit, across every
//!   estimation mode (unfused / scheduled / memory-aware /
//!   distributed), against the legacy constructors that still exist
//!   (`ScaleConfig::tpu_v4`, `MemoryConfig::tpu_v4`,
//!   `SliceConfig::ring` with the historical defaults).
//! * **Scenario diversity with invariants** — every preset produces a
//!   self-consistent report: the exact
//!   `compute-only <= memory-aware <= serialized-bound` bracket, the
//!   1-chip distributed bit-identity, and parallel efficiency in
//!   `(0, 1]`.
//!
//! Plus the checked-in `rust/devices/*.toml` files round-tripping to
//! the registry presets, and the shared-cache no-aliasing regression.

use std::path::PathBuf;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::Estimator;
use scalesim_tpu::device::{load_device_file, DeviceSpec, PRESET_NAMES};
use scalesim_tpu::distributed::{estimate_module_distributed, SliceConfig};
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::graph::{schedule_estimate, EngineConfig};
use scalesim_tpu::memory::{schedule_estimate_memory, MemoryConfig};
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};

fn estimator() -> Estimator {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
}

fn bert() -> ModuleInfo {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/bert_layer.mlir");
    parse_module(&std::fs::read_to_string(path).unwrap()).unwrap()
}

#[test]
fn tpu_v4_is_bit_identical_to_the_pre_refactor_paths_in_every_mode() {
    let module = bert();
    let spec = DeviceSpec::tpu_v4();

    // Pre-refactor shape: estimator built straight from the hard-coded
    // ScaleConfig, memory/slice configs from their legacy constructors.
    let legacy = estimator();
    let legacy_unfused = legacy.estimate_module(&module);
    let legacy_sched = schedule_estimate(&module, &legacy_unfused, EngineConfig::Tpu);
    let legacy_mem = schedule_estimate_memory(
        &module,
        &legacy_unfused,
        EngineConfig::Tpu,
        &MemoryConfig::tpu_v4(),
    );
    let legacy_dist =
        estimate_module_distributed(&legacy, &module, &SliceConfig::ring(4, 100.0));

    // Post-refactor shape: everything derived from the spec.
    let est = estimator().retarget(&spec);
    let unfused = est.estimate_module(&module);
    let sched = schedule_estimate(&module, &unfused, EngineConfig::for_device(&spec));
    let mem = schedule_estimate_memory(
        &module,
        &unfused,
        EngineConfig::for_device(&spec),
        &spec.memory_config(),
    );
    let dist = estimate_module_distributed(&est, &module, &spec.slice_config(4, None).unwrap());

    assert_eq!(unfused.total_us.to_bits(), legacy_unfused.total_us.to_bits());
    for (a, b) in unfused.ops.iter().zip(&legacy_unfused.ops) {
        assert_eq!(a.latency_us.to_bits(), b.latency_us.to_bits(), "{}", a.op_name);
        assert_eq!(a.cycles, b.cycles, "{}", a.op_name);
    }
    assert_eq!(sched.makespan_us.to_bits(), legacy_sched.makespan_us.to_bits());
    assert_eq!(
        sched.critical_path_us.to_bits(),
        legacy_sched.critical_path_us.to_bits()
    );
    assert_eq!(mem.makespan_us().to_bits(), legacy_mem.makespan_us().to_bits());
    assert_eq!(
        mem.serialized_bound_us.to_bits(),
        legacy_mem.serialized_bound_us.to_bits()
    );
    assert_eq!(mem.stats, legacy_mem.stats);
    assert_eq!(dist.total_us.to_bits(), legacy_dist.total_us.to_bits());
    assert_eq!(
        dist.collective_us.to_bits(),
        legacy_dist.collective_us.to_bits()
    );
}

#[test]
fn every_preset_satisfies_the_exact_invariant_suite() {
    let module = bert();
    let base = estimator();
    for spec in DeviceSpec::presets() {
        let est = base.retarget(&spec);
        let report = est.estimate_module(&module);
        assert!(report.total_us > 0.0, "{}: empty estimate", spec.name);

        let engines = EngineConfig::for_device(&spec);
        let sched = schedule_estimate(&module, &report, engines);
        let mem = schedule_estimate_memory(&module, &report, engines, &spec.memory_config());
        // The exact bracket (bit-level monotonicity, no epsilons): the
        // same invariant tests/memory_model.rs proves for tpu-v4 must
        // hold for every device the spec layer can produce.
        assert!(
            sched.makespan_us <= mem.makespan_us(),
            "{}: compute-only {} > memory-aware {}",
            spec.name,
            sched.makespan_us,
            mem.makespan_us()
        );
        assert!(
            mem.makespan_us() <= mem.serialized_bound_us,
            "{}: memory-aware {} > serialized bound {}",
            spec.name,
            mem.makespan_us(),
            mem.serialized_bound_us
        );

        // Distributed: one chip is bit-identical to the single-chip
        // walk on this device; four chips stay self-consistent.
        let one = estimate_module_distributed(&est, &module, &spec.slice_config(1, None).unwrap());
        assert_eq!(
            one.total_us.to_bits(),
            report.total_us.to_bits(),
            "{}: 1-chip slice diverged",
            spec.name
        );
        let four = estimate_module_distributed(&est, &module, &spec.slice_config(4, None).unwrap());
        let eff = four.parallel_efficiency();
        assert!(eff > 0.0 && eff <= 1.0, "{}: efficiency {eff}", spec.name);
        assert!(
            four.critical_path_us <= four.total_us,
            "{}: critical path exceeds makespan",
            spec.name
        );
    }
}

#[test]
fn presets_actually_differ_from_the_reference() {
    let module = bert();
    let base = estimator();
    let v4 = base.estimate_module(&module).total_us;
    for name in ["tpu-v5e", "tpu-v5p", "generic-256x256"] {
        let spec = DeviceSpec::preset(name).unwrap();
        let total = base.retarget(&spec).estimate_module(&module).total_us;
        assert_ne!(
            total.to_bits(),
            v4.to_bits(),
            "{name} produced the reference estimate"
        );
    }
}

#[test]
fn shared_cache_mixing_devices_never_aliases_same_shape() {
    // The satellite regression: two devices, one cache, one shape.
    use scalesim_tpu::frontend::classify::OpClass;
    let base = estimator();
    let v5e = base.retarget(&DeviceSpec::tpu_v5e());
    let class = OpClass::SystolicGemm {
        gemm: GemmShape::new(512, 512, 512),
        count: 1,
    };
    let a = base.estimate_op(0, "dot", &class).latency_us;
    let b = v5e.estimate_op(0, "dot", &class).latency_us;
    assert_ne!(a.to_bits(), b.to_bits(), "devices aliased one cache entry");
    // Re-asking either device reproduces its own bits (cache hits).
    assert_eq!(base.estimate_op(0, "dot", &class).latency_us.to_bits(), a.to_bits());
    assert_eq!(v5e.estimate_op(0, "dot", &class).latency_us.to_bits(), b.to_bits());
    let stats = base.cache.stats();
    assert_eq!(stats.entries, 2);
    assert_eq!(stats.misses, 2);
    assert_eq!(stats.hits, 2);
}

#[test]
fn checked_in_device_files_match_the_registry() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("devices");
    for name in PRESET_NAMES {
        let path = dir.join(format!("{name}.toml"));
        let spec = load_device_file(&path)
            .unwrap_or_else(|e| panic!("loading {}: {e:#}", path.display()));
        let preset = DeviceSpec::preset(name).unwrap();
        assert_eq!(
            spec.fingerprint(),
            preset.fingerprint(),
            "{name}.toml drifted from the registry preset"
        );
        assert_eq!(spec, preset, "{name}.toml field mismatch");
    }
}
