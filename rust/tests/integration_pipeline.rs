//! Integration: the full modeling pipeline across modules — measure →
//! calibrate → train → parse → route → estimate → serve — without
//! touching the filesystem artifacts (inline StableHLO).

use std::sync::Arc;

use scalesim_tpu::coordinator::{serve_lines, Estimator};
use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::experiments::assets;
use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::scalesim::GemmShape;
use scalesim_tpu::tpu::{Hardware, TpuV4Model};
use scalesim_tpu::util::json::Json;

const MODEL_TEXT: &str = r#"
module @it_model {
  func.func public @main(%x: tensor<64x784xf32>, %w1: tensor<784x512xf32>, %b1: tensor<64x512xf32>, %w2: tensor<512x10xf32>) -> (tensor<64x10xf32>) {
    %0 = stablehlo.dot_general %x, %w1, contracting_dims = [1] x [0] : (tensor<64x784xf32>, tensor<784x512xf32>) -> tensor<64x512xf32>
    %1 = stablehlo.add %0, %b1 : tensor<64x512xf32>
    %cst = stablehlo.constant dense<0.0> : tensor<f32>
    %2 = stablehlo.broadcast_in_dim %cst, dims = [] : (tensor<f32>) -> tensor<64x512xf32>
    %3 = stablehlo.maximum %1, %2 : tensor<64x512xf32>
    %4 = stablehlo.dot_general %3, %w2, contracting_dims = [1] x [0] : (tensor<64x512xf32>, tensor<512x10xf32>) -> tensor<64x10xf32>
    return %4 : tensor<64x10xf32>
  }
}
"#;

fn build_estimator() -> Estimator {
    let mut hw = TpuV4Model::new(77);
    assets::build_estimator(&mut hw, &DeviceSpec::tpu_v4(), 300, 2, 9)
}

#[test]
fn whole_pipeline_estimates_model() {
    let est = build_estimator();
    let module = parse_module(MODEL_TEXT).unwrap();
    let report = est.estimate_module(&module);

    assert_eq!(report.ops.len(), 6);
    assert!(report.total_us > 0.0);
    assert!(report.systolic_us > 0.0);
    assert!(report.elementwise_us > 0.0);
    // The two GEMMs must dominate this MLP-like graph.
    assert!(report.systolic_us > report.elementwise_us);
    // All elementwise ops covered by learned models (add/maximum trained).
    assert!(report.coverage() > 0.6, "coverage {}", report.coverage());
}

#[test]
fn estimates_are_plausible_vs_device() {
    // The estimator's GEMM predictions should track the device it was
    // calibrated on within a loose band (it IS a model, not the device).
    let est = build_estimator();
    let mut hw = TpuV4Model::new(77);
    for g in [
        GemmShape::new(96, 96, 96),
        GemmShape::new(640, 384, 512),
        GemmShape::new(2048, 1536, 1024),
    ] {
        let cycles = scalesim_tpu::scalesim::simulate_gemm(&est.config, g).total_cycles();
        let predicted = est.calibration.cycles_to_us(&g, cycles);
        let measured = scalesim_tpu::tpu::measure_gemm_median(&mut hw, g, 5);
        let ratio = predicted / measured;
        assert!(
            ratio > 0.5 && ratio < 2.0,
            "{g}: predicted {predicted:.1} vs measured {measured:.1}"
        );
    }
}

#[test]
fn service_round_trip_json() {
    let est = Arc::new(build_estimator());
    let dir = std::env::temp_dir().join("scalesim_it_service");
    std::fs::create_dir_all(&dir).unwrap();
    let module_path = dir.join("model.stablehlo.txt");
    std::fs::write(&module_path, MODEL_TEXT).unwrap();

    let lines = vec![
        r#"{"type":"gemm","m":256,"k":256,"n":256}"#.to_string(),
        format!(r#"{{"type":"module","path":"{}"}}"#, module_path.display()),
        r#"{"type":"elementwise","op":"add","dims":[512,512]}"#.to_string(),
        r#"{"type":"elementwise","op":"tanh","dims":[64,64]}"#.to_string(),
    ];
    let responses = serve_lines(est, &lines, 4);
    assert_eq!(responses.len(), 4);

    let r0 = Json::parse(&responses[0]).unwrap();
    assert_eq!(r0.get("ok"), Some(&Json::Bool(true)));
    assert!(r0.req_f64("cycles").unwrap() > 0.0);

    let r1 = Json::parse(&responses[1]).unwrap();
    assert_eq!(r1.req_str("type").unwrap(), "module");
    assert_eq!(r1.req_f64("num_ops").unwrap(), 6.0);

    let r2 = Json::parse(&responses[2]).unwrap();
    assert_eq!(r2.req_str("source").unwrap(), "learned");

    // tanh has no dedicated model: proxied through add.
    let r3 = Json::parse(&responses[3]).unwrap();
    assert_eq!(r3.req_str("source").unwrap(), "learned-proxy");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn assets_roundtrip_preserves_estimates() {
    let est = build_estimator();
    let dir = std::env::temp_dir().join("scalesim_it_assets");
    std::fs::remove_dir_all(&dir).ok();
    assets::save_assets(&dir, &est).unwrap();
    let est2 = assets::load_assets(&dir).unwrap();

    let module = parse_module(MODEL_TEXT).unwrap();
    let a = est.estimate_module(&module);
    let b = est2.estimate_module(&module);
    assert!((a.total_us - b.total_us).abs() < 1e-9);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hardware_backends_share_one_interface() {
    // The experiments only see `dyn Hardware`; verify object safety and
    // sane outputs through the trait object.
    let mut backends: Vec<Box<dyn Hardware>> = vec![Box::new(TpuV4Model::new(1))];
    for hw in backends.iter_mut() {
        let t = hw.gemm_latency_us(GemmShape::new(128, 128, 128));
        assert!(t.is_finite() && t > 0.0);
        let e = hw.elementwise_latency_us(
            scalesim_tpu::frontend::EwKind::Add,
            &[256, 256],
        );
        assert!(e.is_finite() && e > 0.0);
    }
}
