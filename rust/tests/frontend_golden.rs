//! Golden-file tests for the StableHLO frontend: checked-in `.mlir`
//! fixtures are parsed and classified, and the resulting op counts,
//! shapes, dtypes and classifications are asserted exactly. Any frontend
//! regression that changes what the estimator sees fails here first.

use std::path::Path;

use scalesim_tpu::frontend::types::DType;
use scalesim_tpu::frontend::{
    classify, parse_module, CollectiveKind, ModuleInfo, OpClass, ShardingAttr,
};
use scalesim_tpu::scalesim::GemmShape;

fn fixture(name: &str) -> ModuleInfo {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    parse_module(&text).unwrap_or_else(|e| panic!("parsing {name}: {e}"))
}

/// Histogram of classifications over the entry function.
#[derive(Debug, Default, PartialEq, Eq)]
struct ClassCounts {
    gemm: usize,
    conv: usize,
    elementwise: usize,
    reduction: usize,
    movement: usize,
    collective: usize,
    free: usize,
    unmodeled: usize,
}

fn count_classes(m: &ModuleInfo) -> ClassCounts {
    let mut c = ClassCounts::default();
    for op in &m.entry().unwrap().ops {
        match classify(op) {
            OpClass::SystolicGemm { .. } => c.gemm += 1,
            OpClass::SystolicConv { .. } => c.conv += 1,
            OpClass::Elementwise { .. } => c.elementwise += 1,
            OpClass::Reduction { .. } => c.reduction += 1,
            OpClass::DataMovement { .. } => c.movement += 1,
            OpClass::Collective { .. } => c.collective += 1,
            OpClass::Free => c.free += 1,
            OpClass::Unmodeled { .. } => c.unmodeled += 1,
        }
    }
    c
}

#[test]
fn bert_layer_golden() {
    let m = fixture("bert_layer.mlir");
    assert_eq!(m.name, "bert_layer");
    let f = m.entry().unwrap();
    assert_eq!(f.arg_types.len(), 7);
    assert_eq!(f.ops.len(), 33, "op count drifted");

    assert_eq!(
        count_classes(&m),
        ClassCounts {
            gemm: 8,
            conv: 0,
            elementwise: 7,
            reduction: 2,
            movement: 12,
            collective: 0,
            free: 4,
            unmodeled: 0,
        }
    );

    // Every op that produces a tensor produces bf16.
    for op in &f.ops {
        if let Some(t) = op.out_type() {
            assert_eq!(t.dtype, DType::Bf16, "op {} is not bf16", op.op_name);
        }
    }

    // The eight GEMMs, in program order, with exact shapes and batch
    // counts (the attention dots are 12-way batched).
    let gemms: Vec<(GemmShape, u64)> = f
        .ops
        .iter()
        .filter_map(|op| match classify(op) {
            OpClass::SystolicGemm { gemm, count } => Some((gemm, count)),
            _ => None,
        })
        .collect();
    assert_eq!(
        gemms,
        vec![
            (GemmShape::new(128, 768, 768), 1),  // Q proj
            (GemmShape::new(128, 768, 768), 1),  // K proj
            (GemmShape::new(128, 768, 768), 1),  // V proj
            (GemmShape::new(128, 64, 128), 12),  // QK^T
            (GemmShape::new(128, 128, 64), 12),  // probs * V
            (GemmShape::new(128, 768, 768), 1),  // output proj
            (GemmShape::new(128, 768, 3072), 1), // FFN up
            (GemmShape::new(128, 3072, 768), 1), // FFN down
        ]
    );

    // The FFN-up matmul carries a column-parallel sharding annotation.
    let ffn1 = f
        .ops
        .iter()
        .find(|op| op.sharding.is_some())
        .expect("sharded op present");
    assert_eq!(
        ffn1.sharding,
        Some(ShardingAttr::Devices { mesh: vec![1, 4] })
    );
    assert!(ffn1.sharding.as_ref().unwrap().model_parallel());
}

#[test]
fn sharded_mlp_golden() {
    let m = fixture("sharded_mlp.mlir");
    assert_eq!(m.name, "sharded_mlp");
    let f = m.entry().unwrap();
    assert_eq!(f.ops.len(), 3);

    match classify(&f.ops[0]) {
        OpClass::SystolicGemm { gemm, count } => {
            assert_eq!(gemm, GemmShape::new(512, 1024, 2048));
            assert_eq!(count, 1);
        }
        other => panic!("expected gemm, got {other:?}"),
    }
    assert_eq!(
        f.ops[0].sharding,
        Some(ShardingAttr::Devices { mesh: vec![4, 1] })
    );
    assert!(!f.ops[0].sharding.as_ref().unwrap().model_parallel());

    match classify(&f.ops[1]) {
        OpClass::Elementwise { out, .. } => {
            assert_eq!(out.dims, vec![512, 2048]);
            assert_eq!(out.dtype, DType::Bf16);
        }
        other => panic!("expected elementwise, got {other:?}"),
    }
    assert_eq!(
        f.ops[1].sharding,
        Some(ShardingAttr::Devices { mesh: vec![4, 1] })
    );
    assert_eq!(f.ops[2].sharding, Some(ShardingAttr::Replicated));
}

#[test]
fn collectives_golden() {
    let m = fixture("collectives.mlir");
    assert_eq!(m.name, "collectives");
    let f = m.entry().unwrap();
    assert_eq!(f.ops.len(), 6);

    assert_eq!(
        count_classes(&m),
        ClassCounts {
            gemm: 1,
            conv: 0,
            elementwise: 1,
            reduction: 0,
            movement: 0,
            collective: 4,
            free: 0,
            unmodeled: 0,
        }
    );

    let classes: Vec<OpClass> = f.ops.iter().map(classify).collect();
    match &classes[0] {
        OpClass::Collective { kind, bytes_in, out } => {
            assert_eq!(*kind, CollectiveKind::AllReduce);
            assert_eq!(*bytes_in, 1024 * 1024 * 4);
            assert_eq!(out.size_bytes(), 1024 * 1024 * 4);
            assert_eq!(out.dtype, DType::F32);
        }
        other => panic!("expected all_reduce, got {other:?}"),
    }
    match &classes[1] {
        OpClass::Collective { kind, bytes_in, out } => {
            assert_eq!(*kind, CollectiveKind::AllGather);
            assert_eq!(*bytes_in, 256 * 1024 * 4);
            assert_eq!(out.dims, vec![1024, 1024]);
        }
        other => panic!("expected all_gather, got {other:?}"),
    }
    match &classes[2] {
        OpClass::Collective { kind, out, .. } => {
            assert_eq!(*kind, CollectiveKind::ReduceScatter);
            assert_eq!(out.dims, vec![256, 1024]);
        }
        other => panic!("expected reduce_scatter, got {other:?}"),
    }
    match &classes[3] {
        OpClass::Collective { kind, bytes_in, .. } => {
            assert_eq!(*kind, CollectiveKind::CollectivePermute);
            assert_eq!(*bytes_in, 1024 * 1024 * 4);
        }
        other => panic!("expected collective_permute, got {other:?}"),
    }
    match &classes[5] {
        OpClass::SystolicGemm { gemm, .. } => {
            assert_eq!(*gemm, GemmShape::new(1024, 1024, 1024));
        }
        other => panic!("expected gemm, got {other:?}"),
    }

    // The dimension attributes made it through the generic form.
    assert_eq!(f.ops[1].int_attrs.get("all_gather_dim"), Some(&vec![0]));
    assert_eq!(f.ops[2].int_attrs.get("scatter_dimension"), Some(&vec![0]));
}

#[test]
fn decoder_block_golden() {
    let m = fixture("decoder_block.mlir");
    assert_eq!(m.name, "decoder_block");
    let f = m.entry().unwrap();
    assert_eq!(f.arg_types.len(), 7);
    assert_eq!(f.arg_types[0].dims, vec![256, 1024], "activation is [seq, d_model]");
    assert_eq!(f.ops.len(), 34, "op count drifted");

    assert_eq!(
        count_classes(&m),
        ClassCounts {
            gemm: 8,
            conv: 0,
            elementwise: 7,
            reduction: 2,
            movement: 12,
            collective: 0,
            free: 5,
            unmodeled: 0,
        }
    );

    // The eight GEMMs in program order: QKV projections, the two 8-way
    // batched attention dots, the output projection and the FFN pair.
    let gemms: Vec<(GemmShape, u64)> = f
        .ops
        .iter()
        .filter_map(|op| match classify(op) {
            OpClass::SystolicGemm { gemm, count } => Some((gemm, count)),
            _ => None,
        })
        .collect();
    assert_eq!(
        gemms,
        vec![
            (GemmShape::new(256, 1024, 1024), 1), // Q proj
            (GemmShape::new(256, 1024, 1024), 1), // K proj
            (GemmShape::new(256, 1024, 1024), 1), // V proj
            (GemmShape::new(256, 128, 256), 8),   // QK^T
            (GemmShape::new(256, 256, 128), 8),   // probs * V
            (GemmShape::new(256, 1024, 1024), 1), // output proj
            (GemmShape::new(256, 1024, 4096), 1), // FFN up
            (GemmShape::new(256, 4096, 1024), 1), // FFN down
        ]
    );

    // Everything is bf16 — the KV spec's 2 bytes/element rests on this.
    for op in &f.ops {
        if let Some(t) = op.out_type() {
            assert_eq!(t.dtype, DType::Bf16, "op {} is not bf16", op.op_name);
        }
    }
}

#[test]
fn decode_lowering_classifies_identically_to_prefill() {
    use scalesim_tpu::inference::{lower_decode, sequence_dim};

    let m = fixture("decoder_block.mlir");
    let seq = sequence_dim(&m).unwrap();
    assert_eq!(seq, 256);
    let d = lower_decode(&m);
    assert_eq!(sequence_dim(&d), Some(1));

    let pf = m.entry().unwrap();
    let df = d.entry().unwrap();
    assert_eq!(pf.ops.len(), df.ops.len(), "lowering changed the op list");

    let rewrite = |dims: &[usize]| -> Vec<usize> {
        dims.iter().map(|&x| if x == seq { 1 } else { x }).collect()
    };

    for (a, b) in pf.ops.iter().zip(&df.ops) {
        // Same op, same SSA structure, same attributes...
        assert_eq!(a.op_name, b.op_name);
        assert_eq!(a.dot_dims, b.dot_dims, "{}: dot dims drifted", a.op_name);
        assert_eq!(a.int_attrs, b.int_attrs, "{}: attrs drifted", a.op_name);
        // ...same classification kind...
        let (ca, cb) = (classify(a), classify(b));
        assert_eq!(
            std::mem::discriminant(&ca),
            std::mem::discriminant(&cb),
            "{}: class changed {ca:?} -> {cb:?}",
            a.op_name
        );
        // ...and every type is the prefill type with seq extents
        // rewritten to 1, nothing else.
        assert_eq!(a.operand_types.len(), b.operand_types.len());
        for (ta, tb) in a.operand_types.iter().zip(&b.operand_types) {
            assert_eq!(tb.dims, rewrite(&ta.dims), "{}: operand dims", a.op_name);
            assert_eq!(tb.dtype, ta.dtype);
        }
        for (ta, tb) in a.result_types.iter().zip(&b.result_types) {
            assert_eq!(tb.dims, rewrite(&ta.dims), "{}: result dims", a.op_name);
            assert_eq!(tb.dtype, ta.dtype);
        }
    }

    // The GEMMs collapse to GEMV-shaped ops: each decode gemm is the
    // prefill gemm with seq-derived extents at 1, batch counts intact.
    let shapes = |f: &scalesim_tpu::frontend::FuncInfo| -> Vec<(GemmShape, u64)> {
        f.ops
            .iter()
            .filter_map(|op| match classify(op) {
                OpClass::SystolicGemm { gemm, count } => Some((gemm, count)),
                _ => None,
            })
            .collect()
    };
    let (pg, dg) = (shapes(pf), shapes(df));
    assert_eq!(pg.len(), 8);
    assert_eq!(dg.len(), 8);
    for ((a, ca), (b, cb)) in pg.iter().zip(&dg) {
        assert_eq!(ca, cb, "batch count changed");
        let expect = |x: usize| if x == seq { 1 } else { x };
        assert_eq!(b.m, expect(a.m));
        assert_eq!(b.k, expect(a.k));
        assert_eq!(b.n, expect(a.n));
    }
}
