//! Golden-file tests for the StableHLO frontend: checked-in `.mlir`
//! fixtures are parsed and classified, and the resulting op counts,
//! shapes, dtypes and classifications are asserted exactly. Any frontend
//! regression that changes what the estimator sees fails here first.

use std::path::Path;

use scalesim_tpu::frontend::types::DType;
use scalesim_tpu::frontend::{
    classify, parse_module, CollectiveKind, ModuleInfo, OpClass, ShardingAttr,
};
use scalesim_tpu::scalesim::GemmShape;

fn fixture(name: &str) -> ModuleInfo {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    parse_module(&text).unwrap_or_else(|e| panic!("parsing {name}: {e}"))
}

/// Histogram of classifications over the entry function.
#[derive(Debug, Default, PartialEq, Eq)]
struct ClassCounts {
    gemm: usize,
    conv: usize,
    elementwise: usize,
    reduction: usize,
    movement: usize,
    collective: usize,
    free: usize,
    unmodeled: usize,
}

fn count_classes(m: &ModuleInfo) -> ClassCounts {
    let mut c = ClassCounts::default();
    for op in &m.entry().unwrap().ops {
        match classify(op) {
            OpClass::SystolicGemm { .. } => c.gemm += 1,
            OpClass::SystolicConv { .. } => c.conv += 1,
            OpClass::Elementwise { .. } => c.elementwise += 1,
            OpClass::Reduction { .. } => c.reduction += 1,
            OpClass::DataMovement { .. } => c.movement += 1,
            OpClass::Collective { .. } => c.collective += 1,
            OpClass::Free => c.free += 1,
            OpClass::Unmodeled { .. } => c.unmodeled += 1,
        }
    }
    c
}

#[test]
fn bert_layer_golden() {
    let m = fixture("bert_layer.mlir");
    assert_eq!(m.name, "bert_layer");
    let f = m.entry().unwrap();
    assert_eq!(f.arg_types.len(), 7);
    assert_eq!(f.ops.len(), 33, "op count drifted");

    assert_eq!(
        count_classes(&m),
        ClassCounts {
            gemm: 8,
            conv: 0,
            elementwise: 7,
            reduction: 2,
            movement: 12,
            collective: 0,
            free: 4,
            unmodeled: 0,
        }
    );

    // Every op that produces a tensor produces bf16.
    for op in &f.ops {
        if let Some(t) = op.out_type() {
            assert_eq!(t.dtype, DType::Bf16, "op {} is not bf16", op.op_name);
        }
    }

    // The eight GEMMs, in program order, with exact shapes and batch
    // counts (the attention dots are 12-way batched).
    let gemms: Vec<(GemmShape, u64)> = f
        .ops
        .iter()
        .filter_map(|op| match classify(op) {
            OpClass::SystolicGemm { gemm, count } => Some((gemm, count)),
            _ => None,
        })
        .collect();
    assert_eq!(
        gemms,
        vec![
            (GemmShape::new(128, 768, 768), 1),  // Q proj
            (GemmShape::new(128, 768, 768), 1),  // K proj
            (GemmShape::new(128, 768, 768), 1),  // V proj
            (GemmShape::new(128, 64, 128), 12),  // QK^T
            (GemmShape::new(128, 128, 64), 12),  // probs * V
            (GemmShape::new(128, 768, 768), 1),  // output proj
            (GemmShape::new(128, 768, 3072), 1), // FFN up
            (GemmShape::new(128, 3072, 768), 1), // FFN down
        ]
    );

    // The FFN-up matmul carries a column-parallel sharding annotation.
    let ffn1 = f
        .ops
        .iter()
        .find(|op| op.sharding.is_some())
        .expect("sharded op present");
    assert_eq!(
        ffn1.sharding,
        Some(ShardingAttr::Devices { mesh: vec![1, 4] })
    );
    assert!(ffn1.sharding.as_ref().unwrap().model_parallel());
}

#[test]
fn sharded_mlp_golden() {
    let m = fixture("sharded_mlp.mlir");
    assert_eq!(m.name, "sharded_mlp");
    let f = m.entry().unwrap();
    assert_eq!(f.ops.len(), 3);

    match classify(&f.ops[0]) {
        OpClass::SystolicGemm { gemm, count } => {
            assert_eq!(gemm, GemmShape::new(512, 1024, 2048));
            assert_eq!(count, 1);
        }
        other => panic!("expected gemm, got {other:?}"),
    }
    assert_eq!(
        f.ops[0].sharding,
        Some(ShardingAttr::Devices { mesh: vec![4, 1] })
    );
    assert!(!f.ops[0].sharding.as_ref().unwrap().model_parallel());

    match classify(&f.ops[1]) {
        OpClass::Elementwise { out, .. } => {
            assert_eq!(out.dims, vec![512, 2048]);
            assert_eq!(out.dtype, DType::Bf16);
        }
        other => panic!("expected elementwise, got {other:?}"),
    }
    assert_eq!(
        f.ops[1].sharding,
        Some(ShardingAttr::Devices { mesh: vec![4, 1] })
    );
    assert_eq!(f.ops[2].sharding, Some(ShardingAttr::Replicated));
}

#[test]
fn collectives_golden() {
    let m = fixture("collectives.mlir");
    assert_eq!(m.name, "collectives");
    let f = m.entry().unwrap();
    assert_eq!(f.ops.len(), 6);

    assert_eq!(
        count_classes(&m),
        ClassCounts {
            gemm: 1,
            conv: 0,
            elementwise: 1,
            reduction: 0,
            movement: 0,
            collective: 4,
            free: 0,
            unmodeled: 0,
        }
    );

    let classes: Vec<OpClass> = f.ops.iter().map(classify).collect();
    match &classes[0] {
        OpClass::Collective { kind, bytes_in, out } => {
            assert_eq!(*kind, CollectiveKind::AllReduce);
            assert_eq!(*bytes_in, 1024 * 1024 * 4);
            assert_eq!(out.size_bytes(), 1024 * 1024 * 4);
            assert_eq!(out.dtype, DType::F32);
        }
        other => panic!("expected all_reduce, got {other:?}"),
    }
    match &classes[1] {
        OpClass::Collective { kind, bytes_in, out } => {
            assert_eq!(*kind, CollectiveKind::AllGather);
            assert_eq!(*bytes_in, 256 * 1024 * 4);
            assert_eq!(out.dims, vec![1024, 1024]);
        }
        other => panic!("expected all_gather, got {other:?}"),
    }
    match &classes[2] {
        OpClass::Collective { kind, out, .. } => {
            assert_eq!(*kind, CollectiveKind::ReduceScatter);
            assert_eq!(out.dims, vec![256, 1024]);
        }
        other => panic!("expected reduce_scatter, got {other:?}"),
    }
    match &classes[3] {
        OpClass::Collective { kind, bytes_in, .. } => {
            assert_eq!(*kind, CollectiveKind::CollectivePermute);
            assert_eq!(*bytes_in, 1024 * 1024 * 4);
        }
        other => panic!("expected collective_permute, got {other:?}"),
    }
    match &classes[5] {
        OpClass::SystolicGemm { gemm, .. } => {
            assert_eq!(*gemm, GemmShape::new(1024, 1024, 1024));
        }
        other => panic!("expected gemm, got {other:?}"),
    }

    // The dimension attributes made it through the generic form.
    assert_eq!(f.ops[1].int_attrs.get("all_gather_dim"), Some(&vec![0]));
    assert_eq!(f.ops[2].int_attrs.get("scatter_dimension"), Some(&vec![0]));
}
