# Convenience targets; the Rust crate itself needs only cargo.
#
# The binary surface these targets build (see `scalesim-tpu help`):
#   paper artifacts  table1 / fig2..fig5 / all
#   simulate         one GEMM, a CSV topology, or a StableHLO module
#                    (--json, --timeline, --chips N distributed slices,
#                    --memory for the DMA/residency timeline + roofline)
#   calibrate        build + save modeling assets
#   serve            streaming JSONL estimation service (sharded cache)

.PHONY: build test bench bench-schedule artifacts fmt clippy doc check

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench paper
	cargo bench --bench cache
	cargo bench --bench schedule

# The dependence-graph scheduler throughput numbers (EXPERIMENTS.md
# §Perf Schedule).
bench-schedule:
	cargo bench --bench schedule

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Rustdoc with warnings denied: broken intra-doc links and missing docs
# (the crate sets #![warn(missing_docs)]) fail the build, matching the
# CI `doc` job.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# The CI gate: format, lints, docs and the full test suite.
check: fmt clippy doc test

# AOT-compile the JAX/Pallas workloads into artifacts/ (requires jax).
# Rust tests that consume artifacts self-skip when this has not run.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
