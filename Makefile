# Convenience targets; the Rust crate itself needs only cargo.

.PHONY: build test bench bench-schedule artifacts fmt clippy check

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench paper
	cargo bench --bench cache
	cargo bench --bench schedule

# The dependence-graph scheduler throughput numbers (EXPERIMENTS.md
# §Perf Schedule).
bench-schedule:
	cargo bench --bench schedule

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --all-targets -- -D warnings

# The CI gate: format, lints and the full test suite.
check: fmt clippy test

# AOT-compile the JAX/Pallas workloads into artifacts/ (requires jax).
# Rust tests that consume artifacts self-skip when this has not run.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
