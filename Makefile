# Convenience targets; the Rust crate itself needs only cargo.

.PHONY: build test bench artifacts fmt

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench paper
	cargo bench --bench cache

fmt:
	cargo fmt --all --check

# AOT-compile the JAX/Pallas workloads into artifacts/ (requires jax).
# Rust tests that consume artifacts self-skip when this has not run.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
