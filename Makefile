# Convenience targets; the Rust crate itself needs only cargo.
#
# The binary surface these targets build (see `scalesim-tpu help`):
#   paper artifacts  table1 / fig2..fig5 / all
#   simulate         one GEMM, a CSV topology, or a StableHLO module
#                    (--json, --timeline, --chips N distributed slices,
#                    --memory for the DMA/residency timeline + roofline)
#   calibrate        build + save modeling assets
#   serve            streaming JSONL estimation service (sharded cache;
#                    --listen for the concurrent TCP front end,
#                    --cache-snapshot for warm restarts, --metrics /
#                    --trace for the observability surface)
#   bench-serve      closed-loop load generator for the TCP service
#   llm              request-level LLM serving simulation (prefill/decode
#                    phases, KV-cache residency, continuous batching)
#   bench-llm        the decoder-block serving sweep over every preset

.PHONY: build test bench bench-schedule bench-devices bench-estimator bench-serve bench-llm bench-check devices trace artifacts fmt clippy doc check

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench --bench paper
	cargo bench --bench cache
	cargo bench --bench schedule

# The dependence-graph scheduler throughput numbers (EXPERIMENTS.md
# §Perf Schedule).
bench-schedule:
	cargo bench --bench schedule

# Per-module estimate throughput across the device presets (guards the
# DeviceSpec refactor against per-op lookup overhead).
bench-devices:
	cargo bench --bench device_sweep

# Batched vs scalar estimator core, cache-cold and cache-warm, on the
# bert_layer fixture; publishes BENCH_estimator.json at the repo root
# (CI verifies freshness with `-- --check`). EXPERIMENTS.md §Perf
# Batched estimator records the headline speedup.
bench-estimator:
	cargo bench --bench estimator_batch

# Concurrent-serve throughput/latency: 16 closed-loop clients against an
# in-process TCP server; publishes BENCH_serve.json at the repo root
# (CI verifies freshness with `bench-serve --check`). EXPERIMENTS.md
# §Perf Serve records the headline numbers.
bench-serve: build
	cargo run --release -- bench-serve --clients 16 --requests 2000 --publish

# The LLM serving sweep: the decoder-block fixture served on every
# device preset with the fixed seeded workload; publishes BENCH_llm.json
# at the repo root (CI verifies freshness with `bench-llm --check`).
# EXPERIMENTS.md §LLM serving records the headline tokens/sec + TTFT.
bench-llm: build
	cargo run --release -- bench-llm --publish

# All three published-benchmark freshness gates (BENCH_estimator /
# BENCH_serve / BENCH_llm) in one pass, with the perf-trajectory table —
# the single CI step that replaced the three per-bench checks.
bench-check: build
	cargo run --release -- bench --check-all

# Round-trip every checked-in device file through the loader, verify the
# preset-named ones match the registry, and smoke the compare path
# against all presets (the CI device job).
devices: build
	cargo run --release -- devices --check --dir rust/devices
	cargo run --release -- compare --module rust/tests/fixtures/bert_layer.mlir \
		--chips 4 --shapes 30 --reps 1 --assets target/device-smoke-assets

# Render the BERT-layer fixture's memory-aware schedule as Chrome
# trace-event JSON (target/bert.trace.json) — drag it into
# https://ui.perfetto.dev or chrome://tracing. One lane per engine
# (MXU/VPU/DMA/ICI), critical-path ops flagged, DMA sub-slices and
# residency spills on the DMA lane.
trace: build
	cargo run --release -- simulate \
		--module rust/tests/fixtures/bert_layer.mlir --memory \
		--trace-out target/bert.trace.json

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --all-targets -- -D warnings

# Rustdoc with warnings denied: broken intra-doc links and missing docs
# (the crate sets #![warn(missing_docs)]) fail the build, matching the
# CI `doc` job.
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# The CI gate: format, lints, docs, the full test suite, and the
# published bench freshness gates (all three in one pass).
check: fmt clippy doc test bench-check

# AOT-compile the JAX/Pallas workloads into artifacts/ (requires jax).
# Rust tests that consume artifacts self-skip when this has not run.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts
